//! Minimal binary serialization for tensors and datasets (no serde
//! available offline). Format: magic "MPNO", version u32, then a sequence
//! of named tensor records: name-len u32, name bytes, ndim u32, dims u64…,
//! f32 payload little-endian.
//!
//! The same record stream works over any `Write`/`Read` pair
//! ([`write_tensors_to`]/[`read_tensors_from`]), which is how checkpoints
//! travel as in-memory byte blobs through the distributed wire protocol
//! and the pluggable checkpoint storage backends — the on-disk files and
//! the in-memory blobs are byte-identical.

use crate::tensor::Tensor;
use anyhow::{bail, Context, Result};
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 4] = b"MPNO";
const VERSION: u32 = 1;

/// Write a set of named tensors to a file.
pub fn save_tensors(path: &Path, tensors: &[(&str, &Tensor)]) -> Result<()> {
    let mut f = std::io::BufWriter::new(
        std::fs::File::create(path).with_context(|| format!("create {path:?}"))?,
    );
    write_tensors_to(&mut f, tensors)
}

/// Write the tensor record stream to any sink — same bytes as
/// [`save_tensors`] produces on disk.
pub fn write_tensors_to(f: &mut impl Write, tensors: &[(&str, &Tensor)]) -> Result<()> {
    f.write_all(MAGIC)?;
    f.write_all(&VERSION.to_le_bytes())?;
    f.write_all(&(tensors.len() as u32).to_le_bytes())?;
    for (name, t) in tensors {
        let nb = name.as_bytes();
        f.write_all(&(nb.len() as u32).to_le_bytes())?;
        f.write_all(nb)?;
        f.write_all(&(t.ndim() as u32).to_le_bytes())?;
        for &d in t.shape() {
            f.write_all(&(d as u64).to_le_bytes())?;
        }
        for &v in t.data() {
            f.write_all(&v.to_le_bytes())?;
        }
    }
    Ok(())
}

/// Serialize a tensor set to an in-memory byte blob (the wire/backend
/// form of [`save_tensors`]).
pub fn tensors_to_bytes(tensors: &[(&str, &Tensor)]) -> Result<Vec<u8>> {
    let mut buf = Vec::new();
    write_tensors_to(&mut buf, tensors)?;
    Ok(buf)
}

/// Read all named tensors from a file.
pub fn load_tensors(path: &Path) -> Result<Vec<(String, Tensor)>> {
    let mut f = std::io::BufReader::new(
        std::fs::File::open(path).with_context(|| format!("open {path:?}"))?,
    );
    read_tensors_from(&mut f).with_context(|| format!("read {path:?}"))
}

/// Parse a tensor record stream from any source — the inverse of
/// [`write_tensors_to`].
pub fn read_tensors_from(f: &mut impl Read) -> Result<Vec<(String, Tensor)>> {
    let mut magic = [0u8; 4];
    f.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("not an MPNO tensor stream");
    }
    let ver = read_u32(f)?;
    if ver != VERSION {
        bail!("unsupported version {ver}");
    }
    let count = read_u32(f)? as usize;
    let mut out = Vec::with_capacity(count.min(4096));
    for _ in 0..count {
        let name_len = read_u32(f)? as usize;
        if name_len > 4096 {
            bail!("corrupt name length {name_len}");
        }
        let mut nb = vec![0u8; name_len];
        f.read_exact(&mut nb)?;
        let name = String::from_utf8(nb).context("tensor name not utf8")?;
        let ndim = read_u32(f)? as usize;
        if ndim > 16 {
            bail!("corrupt ndim {ndim}");
        }
        let mut shape = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            let mut b = [0u8; 8];
            f.read_exact(&mut b)?;
            shape.push(u64::from_le_bytes(b) as usize);
        }
        let n: usize = shape.iter().product();
        if n > 1usize << 32 {
            bail!("corrupt element count {n}");
        }
        let mut buf = vec![0u8; n * 4];
        f.read_exact(&mut buf)?;
        let data: Vec<f32> = buf
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        out.push((name, Tensor::from_vec(shape, data)));
    }
    Ok(out)
}

/// Parse a tensor set from an in-memory byte blob (the inverse of
/// [`tensors_to_bytes`]).
pub fn tensors_from_bytes(bytes: &[u8]) -> Result<Vec<(String, Tensor)>> {
    let mut cur = bytes;
    read_tensors_from(&mut cur)
}

fn read_u32(f: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    f.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join("mpno_ser_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.mpno");
        let a = Tensor::from_fn(&[2, 3], |i| (i[0] * 3 + i[1]) as f32 * 0.5);
        let b = Tensor::from_fn(&[4], |i| -(i[0] as f32));
        save_tensors(&path, &[("a", &a), ("bee", &b)]).unwrap();
        let loaded = load_tensors(&path).unwrap();
        assert_eq!(loaded.len(), 2);
        assert_eq!(loaded[0].0, "a");
        assert_eq!(loaded[0].1, a);
        assert_eq!(loaded[1].0, "bee");
        assert_eq!(loaded[1].1, b);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rejects_garbage() {
        let dir = std::env::temp_dir().join("mpno_ser_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("garbage.mpno");
        std::fs::write(&path, b"not a tensor file at all").unwrap();
        assert!(load_tensors(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn empty_set() {
        let dir = std::env::temp_dir().join("mpno_ser_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("empty.mpno");
        save_tensors(&path, &[]).unwrap();
        assert!(load_tensors(&path).unwrap().is_empty());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn byte_blob_matches_file_bytes() {
        // The in-memory form must be byte-identical to the on-disk form:
        // checkpoint blobs shipped over the wire and files written by the
        // storage backend are interchangeable.
        let dir = std::env::temp_dir().join("mpno_ser_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("blob.mpno");
        let a = Tensor::from_fn(&[3, 2], |i| (i[0] as f32) - 0.25 * (i[1] as f32));
        save_tensors(&path, &[("a", &a)]).unwrap();
        let file_bytes = std::fs::read(&path).unwrap();
        let blob = tensors_to_bytes(&[("a", &a)]).unwrap();
        assert_eq!(blob, file_bytes);
        let parsed = tensors_from_bytes(&blob).unwrap();
        assert_eq!(parsed.len(), 1);
        assert_eq!(parsed[0].1, a);
        std::fs::remove_file(&path).ok();
    }
}
