//! Checkpointing: persist/restore training state (fp32 master weights +
//! metadata) with the [`crate::ser`] format, so long runs — and the
//! precision schedule's phase swaps — survive process restarts, and
//! trained models can be served/evaluated later (`mpno eval`).

use crate::runtime::ArtifactEntry;
use crate::tensor::Tensor;
use anyhow::{bail, Context, Result};
use std::path::Path;

/// A saved training state.
#[derive(Debug, Clone)]
pub struct Checkpoint {
    /// Artifact the params belong to (layout contract).
    pub artifact: String,
    pub epoch: usize,
    /// Dynamic loss-scaler state at save time, so a resumed run does not
    /// restart its scale-growth search mid-schedule (absent in
    /// checkpoints written before this field existed).
    pub loss_scale: Option<f64>,
    pub params: Vec<(String, Tensor)>,
    /// Auxiliary `__`-prefixed records this loader does not interpret —
    /// e.g. the distributed runtime's optimizer/rng state
    /// ([`crate::dist::ckpt::TrainState`]). Kept out of `params` so
    /// [`Checkpoint::params_for`] (and with it `mpno eval` / serving)
    /// still sees exactly the model weights, and written back verbatim on
    /// save so round-tripping a file through this struct is lossless.
    pub extras: Vec<(String, Tensor)>,
}

impl Checkpoint {
    pub fn from_params(entry: &ArtifactEntry, epoch: usize, params: &[Tensor]) -> Checkpoint {
        assert_eq!(entry.params.len(), params.len());
        Checkpoint {
            artifact: entry.name.clone(),
            epoch,
            loss_scale: None,
            params: entry
                .params
                .iter()
                .zip(params)
                .map(|(spec, t)| (spec.name.clone(), t.clone()))
                .collect(),
            extras: vec![],
        }
    }

    /// Record the loss scaler's current scale alongside the weights.
    pub fn with_loss_scale(mut self, scale: f64) -> Checkpoint {
        self.loss_scale = Some(scale);
        self
    }

    /// Save to disk. Metadata rides along as tiny tensors so the format
    /// stays a plain named-tensor file.
    ///
    /// `epoch` and `loss_scale` are 64-bit values; an f32 record
    /// truncates non-power-of-two scales and epochs past 2^24. They are
    /// therefore written twice: the legacy f32 records (`__epoch`,
    /// `__loss_scale`), which old readers still understand, and lossless
    /// `__epoch64`/`__loss_scale64` records holding the 64-bit pattern in
    /// two f32 *bit carriers* (see [`bits_to_words`]). [`Checkpoint::load`]
    /// prefers the 64-bit records when present.
    pub fn save(&self, path: &Path) -> Result<()> {
        let meta = self.meta_records();
        crate::ser::save_tensors(path, &self.encode(&meta))
    }

    /// Serialize to an in-memory byte blob — byte-identical to what
    /// [`Checkpoint::save`] writes to disk. This is the form checkpoints
    /// take through the distributed wire protocol and the pluggable
    /// storage backends.
    pub fn to_bytes(&self) -> Result<Vec<u8>> {
        let meta = self.meta_records();
        crate::ser::tensors_to_bytes(&self.encode(&meta))
    }

    fn meta_records(&self) -> Vec<(String, Tensor)> {
        let name_bytes: Vec<f32> = self.artifact.bytes().map(|b| b as f32).collect();
        let mut meta = vec![
            ("__epoch".to_string(), Tensor::from_vec(vec![1], vec![self.epoch as f32])),
            ("__epoch64".to_string(), Tensor::from_vec(vec![2], bits_to_words(self.epoch as u64))),
            ("__artifact".to_string(), Tensor::from_vec(vec![name_bytes.len()], name_bytes)),
        ];
        if let Some(s) = self.loss_scale {
            meta.push(("__loss_scale".to_string(), Tensor::from_vec(vec![1], vec![s as f32])));
            meta.push((
                "__loss_scale64".to_string(),
                Tensor::from_vec(vec![2], bits_to_words(s.to_bits())),
            ));
        }
        meta
    }

    fn encode<'a>(&'a self, meta: &'a [(String, Tensor)]) -> Vec<(&'a str, &'a Tensor)> {
        let own = meta.iter().chain(&self.extras).chain(&self.params);
        own.map(|(n, t)| (n.as_str(), t)).collect()
    }

    pub fn load(path: &Path) -> Result<Checkpoint> {
        Self::from_records(crate::ser::load_tensors(path)?)
    }

    /// Parse from a [`Checkpoint::to_bytes`] blob (or any byte-identical
    /// file image).
    pub fn from_bytes(bytes: &[u8]) -> Result<Checkpoint> {
        Self::from_records(crate::ser::tensors_from_bytes(bytes)?)
    }

    fn from_records(recs: Vec<(String, Tensor)>) -> Result<Checkpoint> {
        let mut epoch = None;
        let mut epoch64 = None;
        let mut artifact = None;
        let mut loss_scale = None;
        let mut loss_scale64 = None;
        let mut params = vec![];
        let mut extras = vec![];
        for (name, t) in recs {
            match name.as_str() {
                "__epoch" => epoch = Some(t.data()[0] as usize),
                "__epoch64" => epoch64 = words_to_bits(&t).map(|b| b as usize),
                "__artifact" => {
                    let bytes: Vec<u8> = t.data().iter().map(|&f| f as u8).collect();
                    artifact = Some(String::from_utf8(bytes).context("artifact name")?);
                }
                "__loss_scale" => loss_scale = Some(t.data()[0] as f64),
                "__loss_scale64" => loss_scale64 = words_to_bits(&t).map(f64::from_bits),
                // Unknown reserved records (e.g. a newer writer's state)
                // stay out of params so weight extraction keeps working.
                _ if name.starts_with("__") => extras.push((name, t)),
                _ => params.push((name, t)),
            }
        }
        Ok(Checkpoint {
            artifact: artifact.context("missing __artifact record")?,
            // The 64-bit records are exact; fall back to the legacy f32
            // ones so checkpoints written before they existed still load.
            epoch: epoch64.or(epoch).context("missing __epoch record")?,
            loss_scale: loss_scale64.or(loss_scale),
            params,
            extras,
        })
    }

    /// Look up an extras record by name.
    pub fn extra(&self, name: &str) -> Option<&Tensor> {
        self.extras.iter().find(|(n, _)| n == name).map(|(_, t)| t)
    }

    /// Extract params in the order an artifact expects, validating both
    /// names and shapes (precision variants of a model share layouts, so a
    /// checkpoint trained mixed restores into the full-precision artifact —
    /// that is how the schedule hands off and how `mpno eval` serves).
    pub fn params_for(&self, entry: &ArtifactEntry) -> Result<Vec<Tensor>> {
        if entry.params.len() != self.params.len() {
            bail!(
                "checkpoint has {} tensors, artifact {} expects {}",
                self.params.len(),
                entry.name,
                entry.params.len()
            );
        }
        entry
            .params
            .iter()
            .map(|spec| {
                let (_, t) = self
                    .params
                    .iter()
                    .find(|(n, _)| n == &spec.name)
                    .with_context(|| format!("checkpoint missing tensor {:?}", spec.name))?;
                if t.shape() != spec.shape.as_slice() {
                    bail!(
                        "shape mismatch for {:?}: checkpoint {:?} vs artifact {:?}",
                        spec.name,
                        t.shape(),
                        spec.shape
                    );
                }
                Ok(t.clone())
            })
            .collect()
    }
}

/// Pack a 64-bit pattern into two f32 *bit carriers* (high word first).
/// The [`crate::ser`] format round-trips f32 bit patterns exactly
/// (`to_le_bytes`/`from_le_bytes`, no arithmetic), so the words survive
/// save/load verbatim even when they happen to encode a NaN. Public so
/// the distributed checkpoint state ([`crate::dist::ckpt`]) can store its
/// own 64-bit counters the same way.
pub fn bits_to_words(bits: u64) -> Vec<f32> {
    vec![f32::from_bits((bits >> 32) as u32), f32::from_bits(bits as u32)]
}

/// Inverse of [`bits_to_words`]; `None` if the record isn't two words.
pub fn words_to_bits(t: &Tensor) -> Option<u64> {
    let d = t.data();
    if d.len() != 2 {
        return None;
    }
    Some(((d[0].to_bits() as u64) << 32) | d[1].to_bits() as u64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::ParamSpec;

    fn fake_entry(names: &[(&str, Vec<usize>)]) -> ArtifactEntry {
        ArtifactEntry {
            name: "fake_mixed_grads".into(),
            file: "x".into(),
            model: "fno".into(),
            dataset: "darcy".into(),
            graph: "grads".into(),
            precision: crate::fp::Precision::Mixed,
            stabilizer: "tanh".into(),
            loss: "h1".into(),
            batch: 4,
            params: names
                .iter()
                .map(|(n, s)| ParamSpec { name: n.to_string(), shape: s.clone(), std: 0.1 })
                .collect(),
            extra_inputs: vec![],
            config: Default::default(),
        }
    }

    #[test]
    fn roundtrip_preserves_everything() {
        let entry = fake_entry(&[("w", vec![2, 3]), ("b", vec![3])]);
        let params = vec![
            Tensor::from_fn(&[2, 3], |i| (i[0] * 3 + i[1]) as f32),
            Tensor::from_fn(&[3], |i| -(i[0] as f32)),
        ];
        let ck = Checkpoint::from_params(&entry, 7, &params);
        let dir = std::env::temp_dir().join("mpno_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("c.mpno");
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back.epoch, 7);
        assert_eq!(back.artifact, "fake_mixed_grads");
        assert_eq!(back.loss_scale, None);
        let restored = back.params_for(&entry).unwrap();
        assert_eq!(restored, params);

        // 64-bit metadata survives losslessly: a loss scale that is not
        // f32-representable and an epoch past f32's 2^24 integer range.
        let scale = 1234.5678_f64;
        assert_ne!(scale as f32 as f64, scale, "test needs a non-f32 scale");
        let big_epoch = (1usize << 40) + 12345;
        let ck2 = Checkpoint::from_params(&entry, big_epoch, &params).with_loss_scale(scale);
        ck2.save(&path).unwrap();
        let back2 = Checkpoint::load(&path).unwrap();
        assert_eq!(back2.epoch, big_epoch);
        assert_eq!(back2.loss_scale, Some(scale));
        assert_eq!(back2.params.len(), 2, "meta records must not leak into params");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn loads_legacy_checkpoints_without_64bit_records() {
        // Files written before __epoch64/__loss_scale64 existed carry only
        // the f32 records; load must still accept them.
        let name: Vec<f32> = "fake_mixed_grads".bytes().map(|b| b as f32).collect();
        let recs: Vec<(&str, Tensor)> = vec![
            ("__epoch", Tensor::from_vec(vec![1], vec![9.0])),
            ("__artifact", Tensor::from_vec(vec![name.len()], name)),
            ("__loss_scale", Tensor::from_vec(vec![1], vec![2048.0])),
            ("w", Tensor::full(&[3], 0.25)),
        ];
        let dir = std::env::temp_dir().join("mpno_ckpt_legacy_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("c.mpno");
        let refs: Vec<(&str, &Tensor)> = recs.iter().map(|(n, t)| (*n, t)).collect();
        crate::ser::save_tensors(&path, &refs).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back.epoch, 9);
        assert_eq!(back.artifact, "fake_mixed_grads");
        assert_eq!(back.loss_scale, Some(2048.0));
        assert_eq!(back.params, vec![("w".to_string(), Tensor::full(&[3], 0.25))]);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn loss_scale_rides_along_without_polluting_params() {
        let entry = fake_entry(&[("w", vec![4])]);
        let params = vec![Tensor::full(&[4], 0.5)];
        let ck = Checkpoint::from_params(&entry, 2, &params).with_loss_scale(4096.0);
        let dir = std::env::temp_dir().join("mpno_ckpt_scale_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("c.mpno");
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back.loss_scale, Some(4096.0));
        assert_eq!(back.params.len(), 1, "__loss_scale must not become a param");
        assert_eq!(back.params_for(&entry).unwrap(), params);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn extras_roundtrip_without_polluting_params() {
        // Reserved (`__`-prefixed) records a loader does not interpret —
        // the distributed runtime's optimizer/rng state — must survive a
        // save/load cycle verbatim AND stay out of params, so the same
        // file still restores into `mpno eval`/serving via params_for.
        let entry = fake_entry(&[("w", vec![4])]);
        let params = vec![Tensor::full(&[4], 0.5)];
        let mut ck = Checkpoint::from_params(&entry, 3, &params);
        ck.extras.push(("__x_rng".into(), Tensor::from_vec(vec![2], bits_to_words(0xDEAD_BEEF))));
        ck.extras.push(("__x_adam_t".into(), Tensor::from_vec(vec![2], bits_to_words(42))));
        let blob = ck.to_bytes().unwrap();
        let back = Checkpoint::from_bytes(&blob).unwrap();
        assert_eq!(back.params.len(), 1, "extras must not leak into params");
        assert_eq!(back.extras.len(), 2);
        assert_eq!(words_to_bits(back.extra("__x_rng").unwrap()), Some(0xDEAD_BEEF));
        assert_eq!(words_to_bits(back.extra("__x_adam_t").unwrap()), Some(42));
        assert_eq!(back.params_for(&entry).unwrap(), params);
        // Byte form and file form are interchangeable.
        let dir = std::env::temp_dir().join("mpno_ckpt_extras_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("c.mpno");
        ck.save(&path).unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), blob);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn cross_artifact_restore_by_name() {
        // Params restore into another artifact as long as names+shapes
        // line up, even if the listed order differs.
        let e1 = fake_entry(&[("w", vec![2]), ("b", vec![3])]);
        let e2 = fake_entry(&[("b", vec![3]), ("w", vec![2])]);
        let params = vec![Tensor::full(&[2], 1.0), Tensor::full(&[3], 2.0)];
        let ck = Checkpoint::from_params(&e1, 0, &params);
        let restored = ck.params_for(&e2).unwrap();
        assert_eq!(restored[0], params[1]); // "b" first in e2
        assert_eq!(restored[1], params[0]);
    }

    #[test]
    fn rejects_shape_mismatch() {
        let e1 = fake_entry(&[("w", vec![2])]);
        let e2 = fake_entry(&[("w", vec![4])]);
        let ck = Checkpoint::from_params(&e1, 0, &[Tensor::full(&[2], 1.0)]);
        assert!(ck.params_for(&e2).is_err());
    }
}
