//! Checkpointing: persist/restore training state (fp32 master weights +
//! metadata) with the [`crate::ser`] format, so long runs — and the
//! precision schedule's phase swaps — survive process restarts, and
//! trained models can be served/evaluated later (`mpno eval`).

use crate::runtime::ArtifactEntry;
use crate::tensor::Tensor;
use anyhow::{bail, Context, Result};
use std::path::Path;

/// A saved training state.
#[derive(Debug, Clone)]
pub struct Checkpoint {
    /// Artifact the params belong to (layout contract).
    pub artifact: String,
    pub epoch: usize,
    /// Dynamic loss-scaler state at save time, so a resumed run does not
    /// restart its scale-growth search mid-schedule (absent in
    /// checkpoints written before this field existed).
    pub loss_scale: Option<f64>,
    pub params: Vec<(String, Tensor)>,
}

impl Checkpoint {
    pub fn from_params(entry: &ArtifactEntry, epoch: usize, params: &[Tensor]) -> Checkpoint {
        assert_eq!(entry.params.len(), params.len());
        Checkpoint {
            artifact: entry.name.clone(),
            epoch,
            loss_scale: None,
            params: entry
                .params
                .iter()
                .zip(params)
                .map(|(spec, t)| (spec.name.clone(), t.clone()))
                .collect(),
        }
    }

    /// Record the loss scaler's current scale alongside the weights.
    pub fn with_loss_scale(mut self, scale: f64) -> Checkpoint {
        self.loss_scale = Some(scale);
        self
    }

    /// Save to disk. Metadata rides along as tiny tensors so the format
    /// stays a plain named-tensor file.
    ///
    /// `epoch` and `loss_scale` are 64-bit values; an f32 record
    /// truncates non-power-of-two scales and epochs past 2^24. They are
    /// therefore written twice: the legacy f32 records (`__epoch`,
    /// `__loss_scale`), which old readers still understand, and lossless
    /// `__epoch64`/`__loss_scale64` records holding the 64-bit pattern in
    /// two f32 *bit carriers* (see [`bits_to_words`]). [`Checkpoint::load`]
    /// prefers the 64-bit records when present.
    pub fn save(&self, path: &Path) -> Result<()> {
        let meta = Tensor::from_vec(vec![1], vec![self.epoch as f32]);
        let epoch64 = Tensor::from_vec(vec![2], bits_to_words(self.epoch as u64));
        let name_bytes: Vec<f32> = self.artifact.bytes().map(|b| b as f32).collect();
        let name_t = Tensor::from_vec(vec![name_bytes.len()], name_bytes);
        let scale_t = self
            .loss_scale
            .map(|s| Tensor::from_vec(vec![1], vec![s as f32]));
        let scale64_t = self
            .loss_scale
            .map(|s| Tensor::from_vec(vec![2], bits_to_words(s.to_bits())));
        let mut recs: Vec<(&str, &Tensor)> =
            vec![("__epoch", &meta), ("__epoch64", &epoch64), ("__artifact", &name_t)];
        if let Some(t) = &scale_t {
            recs.push(("__loss_scale", t));
        }
        if let Some(t) = &scale64_t {
            recs.push(("__loss_scale64", t));
        }
        for (n, t) in &self.params {
            recs.push((n.as_str(), t));
        }
        crate::ser::save_tensors(path, &recs)
    }

    pub fn load(path: &Path) -> Result<Checkpoint> {
        let recs = crate::ser::load_tensors(path)?;
        let mut epoch = None;
        let mut epoch64 = None;
        let mut artifact = None;
        let mut loss_scale = None;
        let mut loss_scale64 = None;
        let mut params = vec![];
        for (name, t) in recs {
            match name.as_str() {
                "__epoch" => epoch = Some(t.data()[0] as usize),
                "__epoch64" => epoch64 = words_to_bits(&t).map(|b| b as usize),
                "__artifact" => {
                    let bytes: Vec<u8> = t.data().iter().map(|&f| f as u8).collect();
                    artifact = Some(String::from_utf8(bytes).context("artifact name")?);
                }
                "__loss_scale" => loss_scale = Some(t.data()[0] as f64),
                "__loss_scale64" => loss_scale64 = words_to_bits(&t).map(f64::from_bits),
                _ => params.push((name, t)),
            }
        }
        Ok(Checkpoint {
            artifact: artifact.context("missing __artifact record")?,
            // The 64-bit records are exact; fall back to the legacy f32
            // ones so checkpoints written before they existed still load.
            epoch: epoch64.or(epoch).context("missing __epoch record")?,
            loss_scale: loss_scale64.or(loss_scale),
            params,
        })
    }

    /// Extract params in the order an artifact expects, validating both
    /// names and shapes (precision variants of a model share layouts, so a
    /// checkpoint trained mixed restores into the full-precision artifact —
    /// that is how the schedule hands off and how `mpno eval` serves).
    pub fn params_for(&self, entry: &ArtifactEntry) -> Result<Vec<Tensor>> {
        if entry.params.len() != self.params.len() {
            bail!(
                "checkpoint has {} tensors, artifact {} expects {}",
                self.params.len(),
                entry.name,
                entry.params.len()
            );
        }
        entry
            .params
            .iter()
            .map(|spec| {
                let (_, t) = self
                    .params
                    .iter()
                    .find(|(n, _)| n == &spec.name)
                    .with_context(|| format!("checkpoint missing tensor {:?}", spec.name))?;
                if t.shape() != spec.shape.as_slice() {
                    bail!(
                        "shape mismatch for {:?}: checkpoint {:?} vs artifact {:?}",
                        spec.name,
                        t.shape(),
                        spec.shape
                    );
                }
                Ok(t.clone())
            })
            .collect()
    }
}

/// Pack a 64-bit pattern into two f32 *bit carriers* (high word first).
/// The [`crate::ser`] format round-trips f32 bit patterns exactly
/// (`to_le_bytes`/`from_le_bytes`, no arithmetic), so the words survive
/// save/load verbatim even when they happen to encode a NaN.
fn bits_to_words(bits: u64) -> Vec<f32> {
    vec![f32::from_bits((bits >> 32) as u32), f32::from_bits(bits as u32)]
}

/// Inverse of [`bits_to_words`]; `None` if the record isn't two words.
fn words_to_bits(t: &Tensor) -> Option<u64> {
    let d = t.data();
    if d.len() != 2 {
        return None;
    }
    Some(((d[0].to_bits() as u64) << 32) | d[1].to_bits() as u64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::ParamSpec;

    fn fake_entry(names: &[(&str, Vec<usize>)]) -> ArtifactEntry {
        ArtifactEntry {
            name: "fake_mixed_grads".into(),
            file: "x".into(),
            model: "fno".into(),
            dataset: "darcy".into(),
            graph: "grads".into(),
            precision: crate::fp::Precision::Mixed,
            stabilizer: "tanh".into(),
            loss: "h1".into(),
            batch: 4,
            params: names
                .iter()
                .map(|(n, s)| ParamSpec { name: n.to_string(), shape: s.clone(), std: 0.1 })
                .collect(),
            extra_inputs: vec![],
            config: Default::default(),
        }
    }

    #[test]
    fn roundtrip_preserves_everything() {
        let entry = fake_entry(&[("w", vec![2, 3]), ("b", vec![3])]);
        let params = vec![
            Tensor::from_fn(&[2, 3], |i| (i[0] * 3 + i[1]) as f32),
            Tensor::from_fn(&[3], |i| -(i[0] as f32)),
        ];
        let ck = Checkpoint::from_params(&entry, 7, &params);
        let dir = std::env::temp_dir().join("mpno_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("c.mpno");
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back.epoch, 7);
        assert_eq!(back.artifact, "fake_mixed_grads");
        assert_eq!(back.loss_scale, None);
        let restored = back.params_for(&entry).unwrap();
        assert_eq!(restored, params);

        // 64-bit metadata survives losslessly: a loss scale that is not
        // f32-representable and an epoch past f32's 2^24 integer range.
        let scale = 1234.5678_f64;
        assert_ne!(scale as f32 as f64, scale, "test needs a non-f32 scale");
        let big_epoch = (1usize << 40) + 12345;
        let ck2 = Checkpoint::from_params(&entry, big_epoch, &params).with_loss_scale(scale);
        ck2.save(&path).unwrap();
        let back2 = Checkpoint::load(&path).unwrap();
        assert_eq!(back2.epoch, big_epoch);
        assert_eq!(back2.loss_scale, Some(scale));
        assert_eq!(back2.params.len(), 2, "meta records must not leak into params");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn loads_legacy_checkpoints_without_64bit_records() {
        // Files written before __epoch64/__loss_scale64 existed carry only
        // the f32 records; load must still accept them.
        let name: Vec<f32> = "fake_mixed_grads".bytes().map(|b| b as f32).collect();
        let recs: Vec<(&str, Tensor)> = vec![
            ("__epoch", Tensor::from_vec(vec![1], vec![9.0])),
            ("__artifact", Tensor::from_vec(vec![name.len()], name)),
            ("__loss_scale", Tensor::from_vec(vec![1], vec![2048.0])),
            ("w", Tensor::full(&[3], 0.25)),
        ];
        let dir = std::env::temp_dir().join("mpno_ckpt_legacy_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("c.mpno");
        let refs: Vec<(&str, &Tensor)> = recs.iter().map(|(n, t)| (*n, t)).collect();
        crate::ser::save_tensors(&path, &refs).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back.epoch, 9);
        assert_eq!(back.artifact, "fake_mixed_grads");
        assert_eq!(back.loss_scale, Some(2048.0));
        assert_eq!(back.params, vec![("w".to_string(), Tensor::full(&[3], 0.25))]);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn loss_scale_rides_along_without_polluting_params() {
        let entry = fake_entry(&[("w", vec![4])]);
        let params = vec![Tensor::full(&[4], 0.5)];
        let ck = Checkpoint::from_params(&entry, 2, &params).with_loss_scale(4096.0);
        let dir = std::env::temp_dir().join("mpno_ckpt_scale_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("c.mpno");
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back.loss_scale, Some(4096.0));
        assert_eq!(back.params.len(), 1, "__loss_scale must not become a param");
        assert_eq!(back.params_for(&entry).unwrap(), params);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn cross_artifact_restore_by_name() {
        // Params restore into another artifact as long as names+shapes
        // line up, even if the listed order differs.
        let e1 = fake_entry(&[("w", vec![2]), ("b", vec![3])]);
        let e2 = fake_entry(&[("b", vec![3]), ("w", vec![2])]);
        let params = vec![Tensor::full(&[2], 1.0), Tensor::full(&[3], 2.0)];
        let ck = Checkpoint::from_params(&e1, 0, &params);
        let restored = ck.params_for(&e2).unwrap();
        assert_eq!(restored[0], params[1]); // "b" first in e2
        assert_eq!(restored[1], params[0]);
    }

    #[test]
    fn rejects_shape_mismatch() {
        let e1 = fake_entry(&[("w", vec![2])]);
        let e2 = fake_entry(&[("w", vec![4])]);
        let ck = Checkpoint::from_params(&e1, 0, &[Tensor::full(&[2], 1.0)]);
        assert!(ck.params_for(&e2).is_err());
    }
}
