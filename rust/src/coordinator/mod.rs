//! The training coordinator — L3's event loop.
//!
//! Owns: epoch/step iteration, batch assembly, the grads call (PJRT or
//! native CPU — anything implementing [`Backend`]), the dynamic loss
//! scaler, Adam with fp32 master weights, the NaN watchdog, metric
//! logging, and the paper's **precision schedule** (§4.4): train the
//! first 25% of epochs on the mixed artifact, the middle 50% on the AMP
//! artifact and the final 25% on the full-precision artifact, carrying
//! the fp32 master weights across the executable swaps — possible because
//! every precision variant of a model shares the same parameter list.
//!
//! [`train_grid`] is deliberately single-process: it is the bitwise
//! *oracle* the multi-process data-parallel runtime ([`crate::dist`])
//! must reproduce at every world size (`tests/dist_parity.rs`).

mod checkpoint;

pub use checkpoint::{bits_to_words, words_to_bits, Checkpoint};

use crate::amp::GradScaler;
use crate::data::{BatchIter, GridDataset};
use crate::metrics;
use crate::optim::{Adam, GradAccumulator};
use crate::rng::Rng;
use crate::runtime::{Backend, ExecLike};
use crate::stability::DivergenceDetector;
use crate::tensor::Tensor;
use anyhow::{bail, Context, Result};
use std::time::Instant;

/// Precision schedule: ordered (start_fraction, artifact name).
#[derive(Debug, Clone)]
pub struct PrecisionSchedule {
    pub phases: Vec<(f64, String)>,
}

impl PrecisionSchedule {
    /// Build a schedule from (start_fraction, artifact) phases. Phases
    /// are sorted by start fraction here, because [`PrecisionSchedule::active`]
    /// scans in order and would silently mis-select on unsorted input.
    /// Non-finite fractions are rejected (they have no defined order).
    pub fn new(mut phases: Vec<(f64, String)>) -> Self {
        assert!(!phases.is_empty(), "schedule needs at least one phase");
        assert!(
            phases.iter().all(|(f, _)| f.is_finite()),
            "phase fractions must be finite"
        );
        phases.sort_by(|a, b| a.0.partial_cmp(&b.0).expect("finite fractions compare"));
        PrecisionSchedule { phases }
    }

    /// The paper's 25/50/25 schedule.
    pub fn paper_default(mixed: &str, amp: &str, full: &str) -> Self {
        PrecisionSchedule::new(vec![
            (0.0, mixed.to_string()),
            (0.25, amp.to_string()),
            (0.75, full.to_string()),
        ])
    }

    pub fn constant(artifact: &str) -> Self {
        PrecisionSchedule::new(vec![(0.0, artifact.to_string())])
    }

    /// The artifact active at `progress` ∈ [0, 1): the last phase whose
    /// start fraction is ≤ progress (phase starts are inclusive, so
    /// progress 0.25 / 0.75 select the amp / full phases of the paper
    /// schedule).
    pub fn active(&self, progress: f64) -> &str {
        let mut current = &self.phases[0].1;
        for (frac, name) in &self.phases {
            if progress >= *frac {
                current = name;
            }
        }
        current
    }
}

/// Training configuration.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    pub schedule: PrecisionSchedule,
    /// fwd artifact used for evaluation (usually the full-precision one).
    pub eval_artifact: Option<String>,
    pub epochs: usize,
    pub lr: f64,
    /// Multiplicative per-epoch learning-rate decay (1.0 = constant).
    pub lr_decay: f64,
    pub seed: u64,
    pub loss_scaling: bool,
    pub init_loss_scale: f64,
    pub grad_clip: f64,
    pub accumulate: usize,
    pub log_path: Option<std::path::PathBuf>,
    /// Save a checkpoint here after every epoch (and restore from it at
    /// startup if present and layout-compatible).
    pub checkpoint_path: Option<std::path::PathBuf>,
    /// Stop early once the watchdog declares divergence.
    pub stop_on_divergence: bool,
}

impl TrainConfig {
    pub fn new(artifact: &str) -> TrainConfig {
        TrainConfig {
            schedule: PrecisionSchedule::constant(artifact),
            eval_artifact: None,
            epochs: 5,
            lr: 1e-3,
            lr_decay: 1.0,
            seed: 0,
            loss_scaling: false,
            init_loss_scale: 65536.0,
            grad_clip: 0.0,
            accumulate: 1,
            log_path: None,
            checkpoint_path: None,
            stop_on_divergence: true,
        }
    }
}

/// Per-epoch record.
#[derive(Debug, Clone)]
pub struct EpochStats {
    pub epoch: usize,
    pub artifact: String,
    pub train_loss: f64,
    pub test_l2: f64,
    pub test_h1: f64,
    pub seconds: f64,
    pub samples_per_sec: f64,
    pub skipped_steps: usize,
}

/// Full training report.
#[derive(Debug)]
pub struct TrainReport {
    pub epochs: Vec<EpochStats>,
    pub params: Vec<Tensor>,
    pub diverged: bool,
    pub diverged_at_step: Option<usize>,
    pub scaler_history: Vec<(u64, f64)>,
    pub total_seconds: f64,
}

impl TrainReport {
    pub fn final_test_l2(&self) -> f64 {
        self.epochs.last().map(|e| e.test_l2).unwrap_or(f64::NAN)
    }

    pub fn final_test_h1(&self) -> f64 {
        self.epochs.last().map(|e| e.test_h1).unwrap_or(f64::NAN)
    }

    pub fn mean_throughput(&self) -> f64 {
        if self.epochs.is_empty() {
            return 0.0;
        }
        self.epochs.iter().map(|e| e.samples_per_sec).sum::<f64>() / self.epochs.len() as f64
    }
}

/// Train a grid model (FNO/TFNO/SFNO/U-Net) per the config, on any
/// [`Backend`] — the PJRT engine's AOT artifacts and the native CPU
/// engine's precision variants run through the same loop, loss scaler,
/// optimizer and checkpointing.
pub fn train_grid<B: Backend>(
    engine: &mut B,
    train: &GridDataset,
    test: &GridDataset,
    cfg: &TrainConfig,
) -> Result<TrainReport> {
    let first = cfg.schedule.phases[0].1.clone();
    let first_exe = engine.load(&first)?;
    let entry = first_exe.entry().clone();
    if entry.graph != "grads" {
        bail!("{first}: schedule must reference grads artifacts");
    }
    let batch = entry.batch;
    let mut params = engine.init_params(&entry, cfg.seed);
    let mut start_epoch = 0usize;
    let mut restored_scale = None;
    if let Some(ck_path) = &cfg.checkpoint_path {
        if ck_path.exists() {
            if let Ok(ck) = Checkpoint::load(ck_path) {
                if let Ok(restored) = ck.params_for(&entry) {
                    params = restored;
                    start_epoch = ck.epoch + 1;
                    restored_scale = ck.loss_scale;
                }
            }
        }
    }
    // Replay the per-epoch decay products sequentially so a resumed run's
    // learning rate is bit-identical to an uninterrupted one (powi would
    // differ in the last ULPs by float non-associativity).
    let mut lr0 = cfg.lr;
    for _ in 0..start_epoch {
        lr0 *= cfg.lr_decay;
    }
    let mut adam = Adam::new(lr0, &params).with_clip(cfg.grad_clip);
    let mut scaler = if cfg.loss_scaling {
        GradScaler::new(cfg.init_loss_scale)
    } else {
        GradScaler::disabled()
    };
    if let Some(s) = restored_scale {
        scaler.set_scale(s);
    }
    let mut accum = GradAccumulator::new(cfg.accumulate);
    let mut watchdog = DivergenceDetector::new(8);
    let mut rng = Rng::new(cfg.seed ^ 0xBA7C4);
    let mut logger = match &cfg.log_path {
        Some(p) => Some(metrics::CsvLogger::create(
            p,
            "epoch,train_loss,test_l2,test_h1,seconds,samples_per_sec",
        )?),
        None => None,
    };

    let mut epochs = vec![];
    let t_total = Instant::now();
    'training: for epoch in start_epoch..cfg.epochs {
        let progress = epoch as f64 / cfg.epochs.max(1) as f64;
        let art_name = cfg.schedule.active(progress).to_string();
        let exe = engine.load(&art_name)?;
        let t0 = Instant::now();
        let mut loss_sum = 0.0;
        let mut steps = 0usize;
        let mut skipped = 0usize;
        let mut samples = 0usize;
        for idx in BatchIter::new(train.len(), batch, &mut rng) {
            let (x, y) = train.gather(&idx);
            let scale_t = Tensor::from_vec(vec![], vec![scaler.loss_scale()]);
            let mut inputs: Vec<&Tensor> = params.iter().collect();
            inputs.push(&x);
            inputs.push(&y);
            inputs.push(&scale_t);
            let out = exe.run(&inputs).with_context(|| format!("step in {art_name}"))?;
            let loss = out[0].data()[0] as f64;
            loss_sum += if loss.is_finite() { loss } else { 0.0 };
            steps += 1;
            samples += idx.len();
            let grads = &out[1..];
            let step_ok = if let Some(acc) = accum.push(grads) {
                adam.step(&mut params, &acc, scaler.inv_scale())
            } else {
                true // mid-accumulation: nothing to apply yet
            };
            if !step_ok {
                skipped += 1;
            }
            scaler.update(step_ok && loss.is_finite());
            if watchdog.observe(loss) && cfg.stop_on_divergence {
                epochs.push(EpochStats {
                    epoch,
                    artifact: art_name.clone(),
                    train_loss: f64::NAN,
                    test_l2: f64::NAN,
                    test_h1: f64::NAN,
                    seconds: t0.elapsed().as_secs_f64(),
                    samples_per_sec: 0.0,
                    skipped_steps: skipped,
                });
                break 'training;
            }
        }
        let seconds = t0.elapsed().as_secs_f64();
        // Evaluate through the *active* phase's artifact, so a schedule's
        // final epochs report metrics at the precision they trained in
        // (not the phase-0 precision captured at startup).
        let (test_l2, test_h1) = evaluate(engine, &params, test, cfg, exe.entry())?;
        let stats = EpochStats {
            epoch,
            artifact: art_name,
            train_loss: loss_sum / steps.max(1) as f64,
            test_l2,
            test_h1,
            seconds,
            samples_per_sec: samples as f64 / seconds,
            skipped_steps: skipped,
        };
        if let Some(log) = logger.as_mut() {
            log.row(&[
                epoch as f64,
                stats.train_loss,
                stats.test_l2,
                stats.test_h1,
                stats.seconds,
                stats.samples_per_sec,
            ])?;
        }
        epochs.push(stats);
        if let Some(ck_path) = &cfg.checkpoint_path {
            let mut ck = Checkpoint::from_params(&entry, epoch, &params);
            // Record the scaler state only when loss scaling is live: a
            // disabled scaler's constant 1.0 must not override a later
            // scaling-enabled resume's init scale.
            if cfg.loss_scaling {
                ck = ck.with_loss_scale(scaler.scale);
            }
            ck.save(ck_path)?;
        }
        if cfg.lr_decay != 1.0 {
            let lr = adam.lr * cfg.lr_decay;
            adam.set_lr(lr);
        }
    }
    Ok(TrainReport {
        diverged: watchdog.diverged(),
        diverged_at_step: watchdog.diverged_at,
        scaler_history: scaler.history.clone(),
        total_seconds: t_total.elapsed().as_secs_f64(),
        epochs,
        params,
    })
}

/// Evaluate params on a test set with the fwd artifact; returns (L2, H1).
pub fn evaluate<B: Backend>(
    engine: &mut B,
    params: &[Tensor],
    test: &GridDataset,
    cfg: &TrainConfig,
    train_entry: &crate::runtime::ArtifactEntry,
) -> Result<(f64, f64)> {
    let eval_name = match &cfg.eval_artifact {
        Some(n) => n.clone(),
        None => {
            // Convention: <model>_<dataset>_..._fwd full-precision twin.
            let mut n = train_entry.name.clone();
            n = n.replace("_grads", "_fwd");
            if engine.manifest().find(&n).is_none() {
                // Fall back to the full-precision fwd for this model/dataset.
                let sel =
                    engine.manifest().select(&train_entry.model, &train_entry.dataset, "fwd");
                let fallback = sel
                    .iter()
                    .find(|a| a.precision == crate::fp::Precision::Full)
                    .or(sel.first())
                    .map(|a| a.name.clone());
                n = fallback.ok_or_else(|| anyhow::anyhow!("no fwd artifact for eval"))?;
            }
            n
        }
    };
    let exe = engine.load(&eval_name)?;
    // Parameter layouts must match the training artifact (CP-factorized or
    // non-default-mode variants have no fwd twin); otherwise fall back to
    // computing the test *loss* through the training grads graph.
    let compatible = exe.entry().params.len() == train_entry.params.len()
        && exe
            .entry()
            .params
            .iter()
            .zip(&train_entry.params)
            .all(|(a, b)| a.shape == b.shape);
    if !compatible {
        return evaluate_via_grads(engine, params, test, train_entry);
    }
    let batch = exe.entry().batch;
    let mut l2 = 0.0;
    let mut h1 = 0.0;
    let mut batches = 0usize;
    let n_eval = test.len().min(4 * batch); // cap eval cost on CPU
    let mut i = 0;
    while i + batch <= n_eval {
        let idx: Vec<usize> = (i..i + batch).collect();
        let (x, y) = test.gather(&idx);
        let mut inputs: Vec<&Tensor> = params.iter().collect();
        inputs.push(&x);
        let out = exe.run(&inputs)?;
        l2 += metrics::relative_l2(&out[0], &y);
        h1 += metrics::relative_h1(&out[0], &y);
        batches += 1;
        i += batch;
    }
    if batches == 0 {
        bail!("test set smaller than one batch");
    }
    Ok((l2 / batches as f64, h1 / batches as f64))
}

/// Fallback test evaluation through the grads artifact's loss output
/// (used when no shape-compatible fwd artifact exists, e.g. CP weights).
/// Returns the test loss in both slots (it is the artifact's configured
/// loss — H1 for NS/Darcy, L2 elsewhere).
fn evaluate_via_grads<B: Backend>(
    engine: &mut B,
    params: &[Tensor],
    test: &GridDataset,
    train_entry: &crate::runtime::ArtifactEntry,
) -> Result<(f64, f64)> {
    let exe = engine.load(&train_entry.name)?;
    let batch = exe.entry().batch;
    let scale = Tensor::from_vec(vec![], vec![1.0f32]);
    let mut loss = 0.0;
    let mut batches = 0usize;
    let mut i = 0;
    while i + batch <= test.len().min(4 * batch) {
        let idx: Vec<usize> = (i..i + batch).collect();
        let (x, y) = test.gather(&idx);
        let mut inputs: Vec<&Tensor> = params.iter().collect();
        inputs.push(&x);
        inputs.push(&y);
        inputs.push(&scale);
        let out = exe.run(&inputs)?;
        loss += out[0].data()[0] as f64;
        batches += 1;
        i += batch;
    }
    if batches == 0 {
        bail!("test set smaller than one batch");
    }
    let l = loss / batches as f64;
    Ok((l, l))
}

/// Zero-shot super-resolution eval (Table 1): run trained params through a
/// fwd artifact at a finer resolution against a high-res dataset.
pub fn evaluate_super_resolution<B: Backend>(
    engine: &mut B,
    params: &[Tensor],
    fwd_artifact: &str,
    hires: &GridDataset,
) -> Result<(f64, f64)> {
    let exe = engine.load(fwd_artifact)?;
    let batch = exe.entry().batch;
    let (h, w) = exe.entry().resolution().context("artifact has no resolution")?;
    let (dh, dw) = hires.resolution();
    if (h, w) != (dh, dw) {
        bail!("artifact is {h}x{w} but dataset is {dh}x{dw}");
    }
    let mut l2 = 0.0;
    let mut h1 = 0.0;
    let mut batches = 0;
    let mut i = 0;
    while i + batch <= hires.len().min(4 * batch) {
        let idx: Vec<usize> = (i..i + batch).collect();
        let (x, y) = hires.gather(&idx);
        let mut inputs: Vec<&Tensor> = params.iter().collect();
        inputs.push(&x);
        let out = exe.run(&inputs)?;
        l2 += metrics::relative_l2(&out[0], &y);
        h1 += metrics::relative_h1(&out[0], &y);
        batches += 1;
        i += batch;
    }
    Ok((l2 / batches as f64, h1 / batches as f64))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{DatasetKind, GenSpec};
    use crate::runtime::Engine;

    fn artifacts_dir() -> std::path::PathBuf {
        std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn have_artifacts() -> bool {
        artifacts_dir().join("manifest.json").exists()
    }

    fn darcy_sets() -> (GridDataset, GridDataset) {
        let spec = GenSpec {
            kind: DatasetKind::DarcyFlow,
            n_samples: 24,
            resolution: 32,
            seed: 7,
        };
        let cache = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("datasets");
        let ds = crate::data::load_or_generate(&spec, &cache).unwrap();
        ds.split(8)
    }

    #[test]
    fn schedule_selects_phases() {
        let s = PrecisionSchedule::paper_default("mixed", "amp", "full");
        assert_eq!(s.active(0.0), "mixed");
        assert_eq!(s.active(0.2), "mixed");
        assert_eq!(s.active(0.25), "amp");
        assert_eq!(s.active(0.5), "amp");
        assert_eq!(s.active(0.75), "full");
        assert_eq!(s.active(0.99), "full");
    }

    #[test]
    fn schedule_boundaries_are_inclusive_phase_starts() {
        // The exact boundary progress values hand off to the next phase.
        let s = PrecisionSchedule::paper_default("mixed", "amp", "full");
        assert_eq!(s.active(0.25), "amp", "0.25 starts the amp phase");
        assert_eq!(s.active(0.75), "full", "0.75 starts the full phase");
        let eps = 1e-12;
        assert_eq!(s.active(0.25 - eps), "mixed");
        assert_eq!(s.active(0.75 - eps), "amp");
    }

    #[test]
    fn schedule_constructor_sorts_unsorted_phases() {
        // Before the sort, `active` scanned in declaration order and an
        // unsorted phase list silently shadowed later fractions.
        let s = PrecisionSchedule::new(vec![
            (0.75, "full".to_string()),
            (0.0, "mixed".to_string()),
            (0.25, "amp".to_string()),
        ]);
        assert_eq!(s.phases[0].1, "mixed");
        assert_eq!(s.active(0.0), "mixed");
        assert_eq!(s.active(0.25), "amp");
        assert_eq!(s.active(0.5), "amp");
        assert_eq!(s.active(0.75), "full");
        assert_eq!(s.active(1.0), "full");
    }

    #[test]
    #[should_panic]
    fn schedule_rejects_empty_phase_list() {
        PrecisionSchedule::new(vec![]);
    }

    #[test]
    #[should_panic]
    fn schedule_rejects_non_finite_fractions() {
        PrecisionSchedule::new(vec![(f64::NAN, "x".to_string())]);
    }

    #[test]
    fn training_reduces_loss_full_precision() {
        if !have_artifacts() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let (train, test) = darcy_sets();
        let mut engine = Engine::new(&artifacts_dir()).unwrap();
        let mut cfg = TrainConfig::new("fno_darcy_r32_full_none_grads");
        cfg.epochs = 6;
        cfg.lr = 2e-3;
        let report = train_grid(&mut engine, &train, &test, &cfg).unwrap();
        assert!(!report.diverged);
        let first = report.epochs.first().unwrap().train_loss;
        let last = report.epochs.last().unwrap().train_loss;
        assert!(
            last < first * 0.9,
            "loss should drop: {first} -> {last}"
        );
        assert!(report.final_test_l2().is_finite());
    }

    #[test]
    fn mixed_training_works_with_tanh() {
        if !have_artifacts() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let (train, test) = darcy_sets();
        let mut engine = Engine::new(&artifacts_dir()).unwrap();
        let mut cfg = TrainConfig::new("fno_darcy_r32_mixed_tanh_grads");
        cfg.epochs = 4;
        cfg.lr = 2e-3;
        cfg.loss_scaling = true;
        let report = train_grid(&mut engine, &train, &test, &cfg).unwrap();
        assert!(!report.diverged, "tanh-stabilized mixed must not diverge");
        let first = report.epochs.first().unwrap().train_loss;
        let last = report.epochs.last().unwrap().train_loss;
        assert!(last < first, "{first} -> {last}");
    }

    #[test]
    fn precision_schedule_swaps_artifacts() {
        if !have_artifacts() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let (train, test) = darcy_sets();
        let mut engine = Engine::new(&artifacts_dir()).unwrap();
        let mut cfg = TrainConfig::new("fno_darcy_r32_mixed_tanh_grads");
        cfg.schedule = PrecisionSchedule::paper_default(
            "fno_darcy_r32_mixed_tanh_grads",
            "fno_darcy_r32_amp_none_grads",
            "fno_darcy_r32_full_none_grads",
        );
        cfg.epochs = 4;
        let report = train_grid(&mut engine, &train, &test, &cfg).unwrap();
        let used: Vec<&str> = report.epochs.iter().map(|e| e.artifact.as_str()).collect();
        assert!(used[0].contains("mixed"));
        assert!(used[1].contains("amp"));
        assert!(used[3].contains("full"));
        assert!(!report.diverged);
    }
}
