//! Mode-truncated separable 2-D passes over planned FFTs.
//!
//! FNO keeps only the `k_max` lowest positive and negative frequencies
//! per axis (16 of 128 in the paper's NS config), so most of a full
//! `fft2`'s second pass — and most of a full `ifft2`'s first pass — is
//! spent computing coefficients that are immediately discarded (forward)
//! or known to be zero (inverse). The kept-mode passes here exploit that
//! structurally:
//!
//! * **forward** ([`fft2_kept`]): row pass over all `h` rows (every
//!   kept coefficient depends on every input column), then the column
//!   pass only over the kept columns — `kept_cols.len()` instead of `w`
//!   length-`h` transforms — then gather the kept rows;
//! * **inverse** ([`ifft2_kept`]): scatter the kept block into zeroed
//!   full-width rows and row-transform only the kept rows —
//!   `kept_rows.len()` instead of `h` length-`w` transforms — then
//!   column-transform all `w` columns (every output sample depends on
//!   every kept row).
//!
//! # Parity with the serial composed oracle
//!
//! Each 1-D transform consumes exactly the values the full-grid pass
//! would (zeros where the embedded spectrum is zero) through the same
//! planned kernel, which is itself bit-identical to the ad-hoc serial
//! `fft`/`ifft` (see [`super::plan`]). Hence
//! `fft2_kept == truncate_modes(fft2(..))` and
//! `ifft2_kept == ifft2(embed_modes(..))` hold bit-for-bit at every
//! [`Scalar`] precision, up to the sign of exact zeros: the oracle's row
//! pass over an all-zero row can produce `-0.0` components where the
//! truncated path skips the row and keeps `+0.0`. Signed zeros are
//! indistinguishable to every downstream add/sub/mul chain in this
//! crate, and `tests/spectral_parity.rs` asserts `to_f64` equality.

use super::plan::Plan;
use crate::fp::{Cplx, Scalar};

/// FFT-order indices of the `2·k_max` kept frequencies on an axis of
/// length `n`: the positive block `[0, k_max)` then the negative block
/// `[n − k_max, n)`. `2·k_max == n` yields the identity ordering.
pub fn kept_indices(n: usize, k_max: usize) -> Vec<usize> {
    assert!(k_max >= 1, "k_max must be >= 1");
    assert!(2 * k_max <= n, "2*k_max={} exceeds axis length {n}", 2 * k_max);
    (0..k_max).chain(n - k_max..n).collect()
}

/// Reusable buffers for the kept-mode passes; grown on demand, never
/// shrunk, so one arena serves a whole batch of transforms (the
/// per-worker scratch of the fused spectral engine).
#[derive(Debug)]
pub struct SpectralScratch<S: Scalar> {
    /// Row-pass intermediate (forward: `h·w`; inverse: `kept_rows·w`).
    rows: Vec<Cplx<S>>,
    /// One gathered column / scattered line (`max(h, w)`).
    line: Vec<Cplx<S>>,
    /// Bluestein convolution scratch for the 1-D plans.
    blue: Vec<Cplx<S>>,
}

impl<S: Scalar> SpectralScratch<S> {
    pub fn new() -> Self {
        SpectralScratch { rows: Vec::new(), line: Vec::new(), blue: Vec::new() }
    }
}

impl<S: Scalar> Default for SpectralScratch<S> {
    fn default() -> Self {
        Self::new()
    }
}

fn grow<S: Scalar>(buf: &mut Vec<Cplx<S>>, len: usize) {
    if buf.len() < len {
        buf.resize(len, Cplx::zero());
    }
}

/// Forward 2-D DFT of a row-major (h, w) buffer, keeping only the
/// (kept_rows × kept_cols) block of the spectrum. `out` is row-major
/// (kept_rows.len(), kept_cols.len()), `out[i][j]` holding coefficient
/// (kept_rows[i], kept_cols[j]) of the full transform. `row_plan` /
/// `col_plan` must be forward plans of length `w` / `h`.
pub fn fft2_kept<S: Scalar>(
    src: &[Cplx<S>],
    h: usize,
    w: usize,
    kept_rows: &[usize],
    kept_cols: &[usize],
    row_plan: &Plan<S>,
    col_plan: &Plan<S>,
    out: &mut [Cplx<S>],
    scratch: &mut SpectralScratch<S>,
) {
    assert_eq!(src.len(), h * w);
    assert_eq!(row_plan.len(), w, "row plan length");
    assert_eq!(col_plan.len(), h, "col plan length");
    assert!(!row_plan.is_inverse() && !col_plan.is_inverse(), "need forward plans");
    let (kr, kc) = (kept_rows.len(), kept_cols.len());
    assert_eq!(out.len(), kr * kc);
    let SpectralScratch { rows, line, blue } = scratch;
    // Row pass in full: every kept coefficient mixes all w input columns.
    grow(rows, h * w);
    rows[..h * w].copy_from_slice(src);
    for r in 0..h {
        row_plan.apply(&mut rows[r * w..(r + 1) * w], blue);
    }
    // Column pass on the kept columns only.
    grow(line, h);
    for (j, &c) in kept_cols.iter().enumerate() {
        for r in 0..h {
            line[r] = rows[r * w + c];
        }
        col_plan.apply(&mut line[..h], blue);
        for (i, &r) in kept_rows.iter().enumerate() {
            out[i * kc + j] = line[r];
        }
    }
}

/// Inverse of [`fft2_kept`]: treat `spec` (row-major kept_rows × kept_cols)
/// as the only nonzero block of a full (h, w) spectrum and inverse-
/// transform to the full grid in `out`. `row_plan` / `col_plan` must be
/// inverse plans of length `w` / `h`.
pub fn ifft2_kept<S: Scalar>(
    spec: &[Cplx<S>],
    h: usize,
    w: usize,
    kept_rows: &[usize],
    kept_cols: &[usize],
    row_plan: &Plan<S>,
    col_plan: &Plan<S>,
    out: &mut [Cplx<S>],
    scratch: &mut SpectralScratch<S>,
) {
    let (kr, kc) = (kept_rows.len(), kept_cols.len());
    assert_eq!(spec.len(), kr * kc);
    assert_eq!(out.len(), h * w);
    assert_eq!(row_plan.len(), w, "row plan length");
    assert_eq!(col_plan.len(), h, "col plan length");
    assert!(row_plan.is_inverse() && col_plan.is_inverse(), "need inverse plans");
    let SpectralScratch { rows, line, blue } = scratch;
    // Row pass on the kept rows only: all other rows of the embedded
    // spectrum are zero and inverse-transform to exact zeros.
    grow(rows, kr * w);
    for i in 0..kr {
        let row = &mut rows[i * w..(i + 1) * w];
        for v in row.iter_mut() {
            *v = Cplx::zero();
        }
        for (j, &c) in kept_cols.iter().enumerate() {
            row[c] = spec[i * kc + j];
        }
        row_plan.apply(row, blue);
    }
    // Column pass over all w columns, scattering the kept rows into a
    // zeroed length-h line (the zeros other rows would contribute).
    grow(line, h);
    for c in 0..w {
        for v in line[..h].iter_mut() {
            *v = Cplx::zero();
        }
        for (i, &r) in kept_rows.iter().enumerate() {
            line[r] = rows[i * w + c];
        }
        col_plan.apply(&mut line[..h], blue);
        for r in 0..h {
            out[r * w + c] = line[r];
        }
    }
}

/// Gather the (kept_rows × kept_cols) block out of a full (h, w)
/// spectrum — the oracle-side counterpart of [`fft2_kept`].
pub fn truncate_modes<S: Scalar>(
    full: &[Cplx<S>],
    h: usize,
    w: usize,
    kept_rows: &[usize],
    kept_cols: &[usize],
) -> Vec<Cplx<S>> {
    assert_eq!(full.len(), h * w);
    let mut out = Vec::with_capacity(kept_rows.len() * kept_cols.len());
    for &r in kept_rows {
        for &c in kept_cols {
            out.push(full[r * w + c]);
        }
    }
    out
}

/// Scatter a (kept_rows × kept_cols) block into a zeroed full (h, w)
/// spectrum — the oracle-side counterpart of [`ifft2_kept`].
pub fn embed_modes<S: Scalar>(
    trunc: &[Cplx<S>],
    h: usize,
    w: usize,
    kept_rows: &[usize],
    kept_cols: &[usize],
) -> Vec<Cplx<S>> {
    let kc = kept_cols.len();
    assert_eq!(trunc.len(), kept_rows.len() * kc);
    let mut out = vec![Cplx::<S>::zero(); h * w];
    for (i, &r) in kept_rows.iter().enumerate() {
        for (j, &c) in kept_cols.iter().enumerate() {
            out[r * w + c] = trunc[i * kc + j];
        }
    }
    out
}

/// Convenience wrapper: symmetric `k_max`-mode truncated forward 2-D FFT
/// using the global plan cache and a fresh scratch. Returns the
/// (2·k_max, 2·k_max) kept block.
pub fn fft2_trunc<S: Scalar>(data: &[Cplx<S>], h: usize, w: usize, k_max: usize) -> Vec<Cplx<S>> {
    let kept_rows = kept_indices(h, k_max);
    let kept_cols = kept_indices(w, k_max);
    let row_plan = super::plan::plan_for::<S>(w, false);
    let col_plan = super::plan::plan_for::<S>(h, false);
    let mut out = vec![Cplx::<S>::zero(); kept_rows.len() * kept_cols.len()];
    let mut scratch = SpectralScratch::new();
    fft2_kept(data, h, w, &kept_rows, &kept_cols, &row_plan, &col_plan, &mut out, &mut scratch);
    out
}

/// Convenience wrapper: inverse of [`fft2_trunc`] back to the full
/// (h, w) grid.
pub fn ifft2_trunc<S: Scalar>(spec: &[Cplx<S>], h: usize, w: usize, k_max: usize) -> Vec<Cplx<S>> {
    let kept_rows = kept_indices(h, k_max);
    let kept_cols = kept_indices(w, k_max);
    let row_plan = super::plan::plan_for::<S>(w, true);
    let col_plan = super::plan::plan_for::<S>(h, true);
    let mut out = vec![Cplx::<S>::zero(); h * w];
    let mut scratch = SpectralScratch::new();
    ifft2_kept(spec, h, w, &kept_rows, &kept_cols, &row_plan, &col_plan, &mut out, &mut scratch);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fft::{fft2, ifft2};
    use crate::rng::Rng;

    fn signal(n: usize, seed: u64) -> Vec<Cplx<f64>> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|_| {
                let (r, i) = rng.cnormal();
                Cplx::from_f64(r, i)
            })
            .collect()
    }

    fn exact(a: &[Cplx<f64>], b: &[Cplx<f64>]) -> bool {
        a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.to_f64() == y.to_f64())
    }

    #[test]
    fn kept_indices_layout() {
        assert_eq!(kept_indices(8, 2), vec![0, 1, 6, 7]);
        assert_eq!(kept_indices(6, 3), vec![0, 1, 2, 3, 4, 5]);
    }

    #[test]
    #[should_panic]
    fn kept_indices_rejects_oversized_k() {
        kept_indices(8, 5);
    }

    #[test]
    fn forward_truncation_matches_full_fft2() {
        for (h, w, k) in [(8usize, 8usize, 2usize), (16, 8, 3), (12, 20, 4), (16, 16, 8)] {
            let x = signal(h * w, (h * w) as u64);
            let mut full = x.clone();
            fft2(&mut full, h, w);
            let want = truncate_modes(&full, h, w, &kept_indices(h, k), &kept_indices(w, k));
            let got = fft2_trunc(&x, h, w, k);
            assert!(exact(&got, &want), "h={h} w={w} k={k}");
        }
    }

    #[test]
    fn inverse_truncation_matches_embedded_full_ifft2() {
        for (h, w, k) in [(8usize, 8usize, 2usize), (16, 8, 3), (12, 20, 4)] {
            let spec = signal(4 * k * k, 99 + (h + w) as u64);
            let mut want = embed_modes(&spec, h, w, &kept_indices(h, k), &kept_indices(w, k));
            ifft2(&mut want, h, w);
            let got = ifft2_trunc(&spec, h, w, k);
            assert!(exact(&got, &want), "h={h} w={w} k={k}");
        }
    }

    #[test]
    fn roundtrip_preserves_band_limited_fields() {
        // A field supported on the kept modes survives truncated fwd+inv.
        let (h, w, k) = (16usize, 16usize, 3usize);
        let x: Vec<Cplx<f64>> = (0..h * w)
            .map(|i| {
                let (r, c) = (i / w, i % w);
                let v = (std::f64::consts::TAU * (r as f64 * 2.0 / h as f64)).cos()
                    + (std::f64::consts::TAU * (c as f64 / w as f64)).sin();
                Cplx::from_f64(v, 0.0)
            })
            .collect();
        let spec = fft2_trunc(&x, h, w, k);
        let back = ifft2_trunc(&spec, h, w, k);
        for (a, b) in back.iter().zip(&x) {
            assert!(a.sub(*b).abs() < 1e-10);
        }
    }

    #[test]
    fn scratch_reuse_is_deterministic() {
        let (h, w, k) = (12usize, 20usize, 4usize);
        let kept_r = kept_indices(h, k);
        let kept_c = kept_indices(w, k);
        let rp = crate::fft::plan_for::<f64>(w, false);
        let cp = crate::fft::plan_for::<f64>(h, false);
        let mut scratch = SpectralScratch::new();
        let x = signal(h * w, 5);
        let y = signal(h * w, 6);
        let mut out_x1 = vec![Cplx::zero(); kept_r.len() * kept_c.len()];
        fft2_kept(&x, h, w, &kept_r, &kept_c, &rp, &cp, &mut out_x1, &mut scratch);
        // Interleave a different transform through the same arena, then
        // repeat x — the arena must not leak state between calls.
        let mut out_y = vec![Cplx::zero(); kept_r.len() * kept_c.len()];
        fft2_kept(&y, h, w, &kept_r, &kept_c, &rp, &cp, &mut out_y, &mut scratch);
        let mut out_x2 = vec![Cplx::zero(); kept_r.len() * kept_c.len()];
        fft2_kept(&x, h, w, &kept_r, &kept_c, &rp, &cp, &mut out_x2, &mut scratch);
        assert!(exact(&out_x1, &out_x2));
    }
}
