//! Mode-truncated separable 2-D passes over planned FFTs.
//!
//! FNO keeps only the `k_max` lowest positive and negative frequencies
//! per axis (16 of 128 in the paper's NS config), so most of a full
//! `fft2`'s second pass — and most of a full `ifft2`'s first pass — is
//! spent computing coefficients that are immediately discarded (forward)
//! or known to be zero (inverse). The kept-mode passes here exploit that
//! structurally:
//!
//! * **forward** ([`fft2_kept`]): row pass over all `h` rows (every
//!   kept coefficient depends on every input column), then the column
//!   pass only over the kept columns — `kept_cols.len()` instead of `w`
//!   length-`h` transforms — then gather the kept rows;
//! * **inverse** ([`ifft2_kept`]): scatter the kept block into zeroed
//!   full-width rows and row-transform only the kept rows —
//!   `kept_rows.len()` instead of `h` length-`w` transforms — then
//!   column-transform all `w` columns (every output sample depends on
//!   every kept row).
//!
//! # Parity with the serial composed oracle
//!
//! Each 1-D transform consumes exactly the values the full-grid pass
//! would (zeros where the embedded spectrum is zero) through the same
//! planned kernel, which is itself bit-identical to the ad-hoc serial
//! `fft`/`ifft` (see [`super::plan`]). Hence
//! `fft2_kept == truncate_modes(fft2(..))` and
//! `ifft2_kept == ifft2(embed_modes(..))` hold bit-for-bit at every
//! [`Scalar`] precision, up to the sign of exact zeros: the oracle's row
//! pass over an all-zero row can produce `-0.0` components where the
//! truncated path skips the row and keeps `+0.0`. Signed zeros are
//! indistinguishable to every downstream add/sub/mul chain in this
//! crate, and `tests/spectral_parity.rs` asserts `to_f64` equality.

use super::plan::Plan;
use crate::fp::lanes;
use crate::fp::{Cplx, Scalar};
use crate::parallel::Executor;

/// FFT-order indices of the `2·k_max` kept frequencies on an axis of
/// length `n`: the positive block `[0, k_max)` then the negative block
/// `[n − k_max, n)`. `2·k_max == n` yields the identity ordering.
pub fn kept_indices(n: usize, k_max: usize) -> Vec<usize> {
    assert!(k_max >= 1, "k_max must be >= 1");
    assert!(2 * k_max <= n, "2*k_max={} exceeds axis length {n}", 2 * k_max);
    (0..k_max).chain(n - k_max..n).collect()
}

/// Reusable buffers for the kept-mode passes; grown on demand, never
/// shrunk, so one arena serves a whole batch of transforms (the
/// per-worker scratch of the fused spectral engine).
#[derive(Debug)]
pub struct SpectralScratch<S: Scalar> {
    /// Row-pass intermediate (forward: `h·w`; inverse: `kept_rows·w`).
    /// Crate-visible so the sibling half-spectrum passes ([`super::half`])
    /// and the parallel pass drivers share one arena.
    pub(crate) rows: Vec<Cplx<S>>,
    /// One gathered column / scattered line (`max(h, w)`).
    pub(crate) line: Vec<Cplx<S>>,
    /// Bluestein convolution scratch for the 1-D plans.
    pub(crate) blue: Vec<Cplx<S>>,
    /// Column-pass staging for the parallel (within-sample fan-out)
    /// variants: column transforms land in contiguous per-column chunks
    /// here instead of the single reused `line`.
    pub(crate) cols: Vec<Cplx<S>>,
}

impl<S: Scalar> SpectralScratch<S> {
    pub fn new() -> Self {
        SpectralScratch { rows: Vec::new(), line: Vec::new(), blue: Vec::new(), cols: Vec::new() }
    }
}

impl<S: Scalar> Default for SpectralScratch<S> {
    fn default() -> Self {
        Self::new()
    }
}

pub(crate) fn grow<S: Scalar>(buf: &mut Vec<Cplx<S>>, len: usize) {
    if buf.len() < len {
        buf.resize(len, Cplx::zero());
    }
}

/// Forward 2-D DFT of a row-major (h, w) buffer, keeping only the
/// (kept_rows × kept_cols) block of the spectrum. `out` is row-major
/// (kept_rows.len(), kept_cols.len()), `out[i][j]` holding coefficient
/// (kept_rows[i], kept_cols[j]) of the full transform. `row_plan` /
/// `col_plan` must be forward plans of length `w` / `h`.
pub fn fft2_kept<S: Scalar>(
    src: &[Cplx<S>],
    h: usize,
    w: usize,
    kept_rows: &[usize],
    kept_cols: &[usize],
    row_plan: &Plan<S>,
    col_plan: &Plan<S>,
    out: &mut [Cplx<S>],
    scratch: &mut SpectralScratch<S>,
) {
    assert_eq!(src.len(), h * w);
    assert_eq!(row_plan.len(), w, "row plan length");
    assert_eq!(col_plan.len(), h, "col plan length");
    assert!(!row_plan.is_inverse() && !col_plan.is_inverse(), "need forward plans");
    let (kr, kc) = (kept_rows.len(), kept_cols.len());
    assert_eq!(out.len(), kr * kc);
    let SpectralScratch { rows, line, blue, .. } = scratch;
    // Row pass in full: every kept coefficient mixes all w input columns.
    grow(rows, h * w);
    rows[..h * w].copy_from_slice(src);
    for r in 0..h {
        row_plan.apply(&mut rows[r * w..(r + 1) * w], blue);
    }
    // Column pass on the kept columns only.
    grow(line, h);
    for (j, &c) in kept_cols.iter().enumerate() {
        for r in 0..h {
            line[r] = rows[r * w + c];
        }
        col_plan.apply(&mut line[..h], blue);
        for (i, &r) in kept_rows.iter().enumerate() {
            out[i * kc + j] = line[r];
        }
    }
}

/// Inverse of [`fft2_kept`]: treat `spec` (row-major kept_rows × kept_cols)
/// as the only nonzero block of a full (h, w) spectrum and inverse-
/// transform to the full grid in `out`. `row_plan` / `col_plan` must be
/// inverse plans of length `w` / `h`.
pub fn ifft2_kept<S: Scalar>(
    spec: &[Cplx<S>],
    h: usize,
    w: usize,
    kept_rows: &[usize],
    kept_cols: &[usize],
    row_plan: &Plan<S>,
    col_plan: &Plan<S>,
    out: &mut [Cplx<S>],
    scratch: &mut SpectralScratch<S>,
) {
    let (kr, kc) = (kept_rows.len(), kept_cols.len());
    assert_eq!(spec.len(), kr * kc);
    assert_eq!(out.len(), h * w);
    assert_eq!(row_plan.len(), w, "row plan length");
    assert_eq!(col_plan.len(), h, "col plan length");
    assert!(row_plan.is_inverse() && col_plan.is_inverse(), "need inverse plans");
    let SpectralScratch { rows, line, blue, .. } = scratch;
    // Row pass on the kept rows only: all other rows of the embedded
    // spectrum are zero and inverse-transform to exact zeros.
    grow(rows, kr * w);
    for i in 0..kr {
        let row = &mut rows[i * w..(i + 1) * w];
        lanes::vfill(row, Cplx::zero());
        for (j, &c) in kept_cols.iter().enumerate() {
            row[c] = spec[i * kc + j];
        }
        row_plan.apply(row, blue);
    }
    // Column pass over all w columns, scattering the kept rows into a
    // zeroed length-h line (the zeros other rows would contribute).
    grow(line, h);
    for c in 0..w {
        lanes::vfill(&mut line[..h], Cplx::zero());
        for (i, &r) in kept_rows.iter().enumerate() {
            line[r] = rows[i * w + c];
        }
        col_plan.apply(&mut line[..h], blue);
        for r in 0..h {
            out[r * w + c] = line[r];
        }
    }
}

/// [`fft2_kept`] with the row and column passes fanned over `ex` —
/// the within-sample fan-out that saturates cores on wide grids when
/// `batch ≪ threads` (one sample cannot feed every worker at sample
/// granularity, but its `h` row transforms and `kept_cols` column
/// transforms are all independent).
///
/// Each 1-D transform runs the same planned kernel on the same values as
/// the serial pass (columns are gathered into contiguous per-column
/// staging chunks instead of the reused `line`, pure data movement), so
/// the result is bit-identical to [`fft2_kept`] at every precision and
/// thread count. Bluestein scratch is per-worker via
/// [`Executor::for_each_chunk_with`].
pub fn fft2_kept_with<S: Scalar>(
    src: &[Cplx<S>],
    h: usize,
    w: usize,
    kept_rows: &[usize],
    kept_cols: &[usize],
    row_plan: &Plan<S>,
    col_plan: &Plan<S>,
    out: &mut [Cplx<S>],
    scratch: &mut SpectralScratch<S>,
    ex: &Executor,
) {
    assert_eq!(src.len(), h * w);
    assert_eq!(row_plan.len(), w, "row plan length");
    assert_eq!(col_plan.len(), h, "col plan length");
    assert!(!row_plan.is_inverse() && !col_plan.is_inverse(), "need forward plans");
    let (kr, kc) = (kept_rows.len(), kept_cols.len());
    assert_eq!(out.len(), kr * kc);
    let SpectralScratch { rows, cols, .. } = scratch;
    // Row pass in full, one work item per row.
    grow(rows, h * w);
    rows[..h * w].copy_from_slice(src);
    ex.for_each_chunk_with(
        &mut rows[..h * w],
        w,
        Vec::new,
        |_, row, blue| row_plan.apply(row, blue),
    );
    // Column pass on the kept columns, one work item per kept column,
    // each gathered into its own contiguous staging chunk.
    grow(cols, kc * h);
    {
        let rows_ro: &[Cplx<S>] = rows;
        ex.for_each_chunk_with(
            &mut cols[..kc * h],
            h,
            Vec::new,
            |j, col, blue| {
                let c = kept_cols[j];
                for (r, v) in col.iter_mut().enumerate() {
                    *v = rows_ro[r * w + c];
                }
                col_plan.apply(col, blue);
            },
        );
    }
    for (i, &r) in kept_rows.iter().enumerate() {
        for j in 0..kc {
            out[i * kc + j] = cols[j * h + r];
        }
    }
}

/// [`ifft2_kept`] with the row and column passes fanned over `ex` (see
/// [`fft2_kept_with`]): bit-identical to the serial pass, columns staged
/// contiguously and transposed back at the end.
pub fn ifft2_kept_with<S: Scalar>(
    spec: &[Cplx<S>],
    h: usize,
    w: usize,
    kept_rows: &[usize],
    kept_cols: &[usize],
    row_plan: &Plan<S>,
    col_plan: &Plan<S>,
    out: &mut [Cplx<S>],
    scratch: &mut SpectralScratch<S>,
    ex: &Executor,
) {
    let (kr, kc) = (kept_rows.len(), kept_cols.len());
    assert_eq!(spec.len(), kr * kc);
    assert_eq!(out.len(), h * w);
    assert_eq!(row_plan.len(), w, "row plan length");
    assert_eq!(col_plan.len(), h, "col plan length");
    assert!(row_plan.is_inverse() && col_plan.is_inverse(), "need inverse plans");
    let SpectralScratch { rows, cols, .. } = scratch;
    // Row pass on the kept rows only, one work item per kept row.
    grow(rows, kr * w);
    ex.for_each_chunk_with(
        &mut rows[..kr * w],
        w,
        Vec::new,
        |i, row, blue| {
            lanes::vfill(row, Cplx::zero());
            for (j, &c) in kept_cols.iter().enumerate() {
                row[c] = spec[i * kc + j];
            }
            row_plan.apply(row, blue);
        },
    );
    // Column pass over all w columns, one work item per column.
    grow(cols, w * h);
    {
        let rows_ro: &[Cplx<S>] = rows;
        ex.for_each_chunk_with(
            &mut cols[..w * h],
            h,
            Vec::new,
            |c, col, blue| {
                lanes::vfill(col, Cplx::zero());
                for (i, &r) in kept_rows.iter().enumerate() {
                    col[r] = rows_ro[i * w + c];
                }
                col_plan.apply(col, blue);
            },
        );
    }
    let cols_ro: &[Cplx<S>] = cols;
    ex.for_each_chunk(out, w, |r, row| {
        for (c, v) in row.iter_mut().enumerate() {
            *v = cols_ro[c * h + r];
        }
    });
}

/// Gather the (kept_rows × kept_cols) block out of a full (h, w)
/// spectrum — the oracle-side counterpart of [`fft2_kept`].
pub fn truncate_modes<S: Scalar>(
    full: &[Cplx<S>],
    h: usize,
    w: usize,
    kept_rows: &[usize],
    kept_cols: &[usize],
) -> Vec<Cplx<S>> {
    assert_eq!(full.len(), h * w);
    let mut out = Vec::with_capacity(kept_rows.len() * kept_cols.len());
    for &r in kept_rows {
        for &c in kept_cols {
            out.push(full[r * w + c]);
        }
    }
    out
}

/// Scatter a (kept_rows × kept_cols) block into a zeroed full (h, w)
/// spectrum — the oracle-side counterpart of [`ifft2_kept`].
pub fn embed_modes<S: Scalar>(
    trunc: &[Cplx<S>],
    h: usize,
    w: usize,
    kept_rows: &[usize],
    kept_cols: &[usize],
) -> Vec<Cplx<S>> {
    let kc = kept_cols.len();
    assert_eq!(trunc.len(), kept_rows.len() * kc);
    let mut out = vec![Cplx::<S>::zero(); h * w];
    for (i, &r) in kept_rows.iter().enumerate() {
        for (j, &c) in kept_cols.iter().enumerate() {
            out[r * w + c] = trunc[i * kc + j];
        }
    }
    out
}

/// Convenience wrapper: symmetric `k_max`-mode truncated forward 2-D FFT
/// using the global plan cache and a fresh scratch. Returns the
/// (2·k_max, 2·k_max) kept block.
pub fn fft2_trunc<S: Scalar>(data: &[Cplx<S>], h: usize, w: usize, k_max: usize) -> Vec<Cplx<S>> {
    let kept_rows = kept_indices(h, k_max);
    let kept_cols = kept_indices(w, k_max);
    let row_plan = super::plan::plan_for::<S>(w, false);
    let col_plan = super::plan::plan_for::<S>(h, false);
    let mut out = vec![Cplx::<S>::zero(); kept_rows.len() * kept_cols.len()];
    let mut scratch = SpectralScratch::new();
    fft2_kept(data, h, w, &kept_rows, &kept_cols, &row_plan, &col_plan, &mut out, &mut scratch);
    out
}

/// Convenience wrapper: inverse of [`fft2_trunc`] back to the full
/// (h, w) grid.
pub fn ifft2_trunc<S: Scalar>(spec: &[Cplx<S>], h: usize, w: usize, k_max: usize) -> Vec<Cplx<S>> {
    let kept_rows = kept_indices(h, k_max);
    let kept_cols = kept_indices(w, k_max);
    let row_plan = super::plan::plan_for::<S>(w, true);
    let col_plan = super::plan::plan_for::<S>(h, true);
    let mut out = vec![Cplx::<S>::zero(); h * w];
    let mut scratch = SpectralScratch::new();
    ifft2_kept(spec, h, w, &kept_rows, &kept_cols, &row_plan, &col_plan, &mut out, &mut scratch);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fft::{fft2, ifft2};
    use crate::rng::Rng;

    fn signal(n: usize, seed: u64) -> Vec<Cplx<f64>> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|_| {
                let (r, i) = rng.cnormal();
                Cplx::from_f64(r, i)
            })
            .collect()
    }

    fn exact(a: &[Cplx<f64>], b: &[Cplx<f64>]) -> bool {
        a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.to_f64() == y.to_f64())
    }

    #[test]
    fn kept_indices_layout() {
        assert_eq!(kept_indices(8, 2), vec![0, 1, 6, 7]);
        assert_eq!(kept_indices(6, 3), vec![0, 1, 2, 3, 4, 5]);
    }

    #[test]
    fn kept_indices_boundary_is_identity_permutation() {
        // 2·k_max == n keeps every frequency, in natural FFT order: the
        // positive block [0, k) runs straight into the negative block
        // [n−k, n) = [k, n).
        for n in [2usize, 4, 6, 8, 10, 16] {
            let got = kept_indices(n, n / 2);
            let want: Vec<usize> = (0..n).collect();
            assert_eq!(got, want, "n={n}");
        }
    }

    #[test]
    fn truncate_embed_roundtrip_exact() {
        // embed ∘ truncate puts every kept coefficient back untouched and
        // leaves exact zeros everywhere else — including odd (Bluestein)
        // axis lengths and the 2·k_max == n boundary.
        for (h, w, k) in [(9usize, 15usize, 4usize), (10, 9, 4), (7, 7, 3), (8, 10, 4)] {
            let kept_r = kept_indices(h, k);
            let kept_c = kept_indices(w, k);
            let spec = signal(kept_r.len() * kept_c.len(), 7 + (h * w) as u64);
            let full = embed_modes(&spec, h, w, &kept_r, &kept_c);
            let back = truncate_modes(&full, h, w, &kept_r, &kept_c);
            assert!(exact(&back, &spec), "h={h} w={w} k={k}");
            let mut kept_cells = 0usize;
            for r in 0..h {
                for c in 0..w {
                    let kept = kept_r.contains(&r) && kept_c.contains(&c);
                    if kept {
                        kept_cells += 1;
                    } else {
                        assert_eq!(full[r * w + c].to_f64(), (0.0, 0.0), "h={h} w={w} ({r},{c})");
                    }
                }
            }
            assert_eq!(kept_cells, spec.len());
        }
    }

    #[test]
    fn kept_passes_handle_odd_axes() {
        // Odd axis lengths exercise the Bluestein plans end-to-end
        // through both truncated passes.
        for (h, w, k) in [(9usize, 15usize, 4usize), (7, 9, 3)] {
            let x = signal(h * w, 31 + (h + w) as u64);
            let mut full = x.clone();
            fft2(&mut full, h, w);
            let want = truncate_modes(&full, h, w, &kept_indices(h, k), &kept_indices(w, k));
            let got = fft2_trunc(&x, h, w, k);
            assert!(exact(&got, &want), "fwd h={h} w={w} k={k}");
            let spec = signal(4 * k * k, 37 + (h + w) as u64);
            let mut winv = embed_modes(&spec, h, w, &kept_indices(h, k), &kept_indices(w, k));
            ifft2(&mut winv, h, w);
            let ginv = ifft2_trunc(&spec, h, w, k);
            assert!(exact(&ginv, &winv), "inv h={h} w={w} k={k}");
        }
    }

    #[test]
    fn parallel_kept_passes_match_serial_bitwise() {
        use crate::parallel::Executor;
        // Wide enough that the within-sample fan-out genuinely spawns
        // workers (h·w ≥ the executor's minimum parallel grain).
        let (h, w, k) = (32usize, 40usize, 5usize);
        let kept_r = kept_indices(h, k);
        let kept_c = kept_indices(w, k);
        let rp = crate::fft::plan_for::<f64>(w, false);
        let cp = crate::fft::plan_for::<f64>(h, false);
        let rpi = crate::fft::plan_for::<f64>(w, true);
        let cpi = crate::fft::plan_for::<f64>(h, true);
        let x = signal(h * w, 41);
        let spec = signal(kept_r.len() * kept_c.len(), 42);
        let mut scratch = SpectralScratch::new();
        let mut want_f = vec![Cplx::zero(); kept_r.len() * kept_c.len()];
        fft2_kept(&x, h, w, &kept_r, &kept_c, &rp, &cp, &mut want_f, &mut scratch);
        let mut want_i = vec![Cplx::zero(); h * w];
        ifft2_kept(&spec, h, w, &kept_r, &kept_c, &rpi, &cpi, &mut want_i, &mut scratch);
        for threads in [1usize, 2, 8] {
            let ex = Executor::new(threads);
            let mut got_f = vec![Cplx::zero(); want_f.len()];
            fft2_kept_with(&x, h, w, &kept_r, &kept_c, &rp, &cp, &mut got_f, &mut scratch, &ex);
            assert!(exact(&got_f, &want_f), "fwd threads={threads}");
            let mut got_i = vec![Cplx::zero(); h * w];
            ifft2_kept_with(
                &spec, h, w, &kept_r, &kept_c, &rpi, &cpi, &mut got_i, &mut scratch, &ex,
            );
            assert!(exact(&got_i, &want_i), "inv threads={threads}");
        }
    }

    #[test]
    #[should_panic]
    fn kept_indices_rejects_oversized_k() {
        kept_indices(8, 5);
    }

    #[test]
    fn forward_truncation_matches_full_fft2() {
        for (h, w, k) in [(8usize, 8usize, 2usize), (16, 8, 3), (12, 20, 4), (16, 16, 8)] {
            let x = signal(h * w, (h * w) as u64);
            let mut full = x.clone();
            fft2(&mut full, h, w);
            let want = truncate_modes(&full, h, w, &kept_indices(h, k), &kept_indices(w, k));
            let got = fft2_trunc(&x, h, w, k);
            assert!(exact(&got, &want), "h={h} w={w} k={k}");
        }
    }

    #[test]
    fn inverse_truncation_matches_embedded_full_ifft2() {
        for (h, w, k) in [(8usize, 8usize, 2usize), (16, 8, 3), (12, 20, 4)] {
            let spec = signal(4 * k * k, 99 + (h + w) as u64);
            let mut want = embed_modes(&spec, h, w, &kept_indices(h, k), &kept_indices(w, k));
            ifft2(&mut want, h, w);
            let got = ifft2_trunc(&spec, h, w, k);
            assert!(exact(&got, &want), "h={h} w={w} k={k}");
        }
    }

    #[test]
    fn roundtrip_preserves_band_limited_fields() {
        // A field supported on the kept modes survives truncated fwd+inv.
        let (h, w, k) = (16usize, 16usize, 3usize);
        let x: Vec<Cplx<f64>> = (0..h * w)
            .map(|i| {
                let (r, c) = (i / w, i % w);
                let v = (std::f64::consts::TAU * (r as f64 * 2.0 / h as f64)).cos()
                    + (std::f64::consts::TAU * (c as f64 / w as f64)).sin();
                Cplx::from_f64(v, 0.0)
            })
            .collect();
        let spec = fft2_trunc(&x, h, w, k);
        let back = ifft2_trunc(&spec, h, w, k);
        for (a, b) in back.iter().zip(&x) {
            assert!(a.sub(*b).abs() < 1e-10);
        }
    }

    #[test]
    fn scratch_reuse_is_deterministic() {
        let (h, w, k) = (12usize, 20usize, 4usize);
        let kept_r = kept_indices(h, k);
        let kept_c = kept_indices(w, k);
        let rp = crate::fft::plan_for::<f64>(w, false);
        let cp = crate::fft::plan_for::<f64>(h, false);
        let mut scratch = SpectralScratch::new();
        let x = signal(h * w, 5);
        let y = signal(h * w, 6);
        let mut out_x1 = vec![Cplx::zero(); kept_r.len() * kept_c.len()];
        fft2_kept(&x, h, w, &kept_r, &kept_c, &rp, &cp, &mut out_x1, &mut scratch);
        // Interleave a different transform through the same arena, then
        // repeat x — the arena must not leak state between calls.
        let mut out_y = vec![Cplx::zero(); kept_r.len() * kept_c.len()];
        fft2_kept(&y, h, w, &kept_r, &kept_c, &rp, &cp, &mut out_y, &mut scratch);
        let mut out_x2 = vec![Cplx::zero(); kept_r.len() * kept_c.len()];
        fft2_kept(&x, h, w, &kept_r, &kept_c, &rp, &cp, &mut out_x2, &mut scratch);
        assert!(exact(&out_x1, &out_x2));
    }
}
