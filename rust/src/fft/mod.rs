//! FFTs generic over precision.
//!
//! The discrete Fourier transform is the paper's central object: FNO
//! replaces the continuous Fourier transform with an FFT over the training
//! grid (incurring the *discretization error* of Thm 3.1), and the paper's
//! method additionally evaluates that FFT in half precision (incurring the
//! *precision error* of Thm 3.2). To measure both, the same FFT code here
//! runs at any [`Scalar`] precision: `fft::<f64>` is the reference,
//! `fft::<F16>` rounds after every butterfly — the "compute in f32, store
//! in half" model of CUDA half arithmetic.
//!
//! Algorithms: iterative radix-2 Cooley–Tukey for power-of-two sizes,
//! Bluestein's chirp-z for everything else, separable row/column passes for
//! 2-D/3-D. A naive O(n²) DFT is kept as the test oracle.
//!
//! The separable passes are embarrassingly parallel: every 1-D transform
//! of a pass is independent. The `*_with` variants ([`fft2_with`],
//! [`fft3_with`], [`fft_batch`], [`fft2_batch`]) dispatch those transforms
//! over a [`crate::parallel::Executor`]; each 1-D transform runs the same
//! serial kernel on the same values in the same order, so the parallel
//! drivers agree with the serial references ([`fft2`], [`fft3`]) at every
//! [`Scalar`] precision (see `tests/parallel_parity.rs`).

//! For repeated transforms of the same size, [`plan`] caches the
//! f64-derived constants (twiddles, bit-reversal, Bluestein chirp and
//! kernel spectra) so results stay bit-identical while the per-butterfly
//! `cos`/`sin` cost disappears, and [`trunc`] provides mode-truncated
//! separable 2-D passes for FNO-style spectral layers (only `k_max`
//! modes per side survive, so most 1-D transforms of the full passes are
//! wasted work). The fused spectral layer built on both lives in
//! [`crate::spectral`].

pub mod half;
pub mod plan;
pub mod trunc;

pub use half::{
    col_weight_factor, half_cols, irfft2_kept, irfft2_kept_with, rfft2_kept, rfft2_kept_with,
    HalfSpectrum,
};
pub use plan::{plan_for, Plan};
pub use trunc::{
    embed_modes, fft2_kept, fft2_kept_with, fft2_trunc, ifft2_kept, ifft2_kept_with, ifft2_trunc,
    kept_indices, truncate_modes, SpectralScratch,
};

use crate::fp::{Cplx, Scalar};
use crate::parallel::Executor;

/// Forward DFT convention: X[k] = Σ_j x[j]·e^{−2πi jk/n} (unnormalized,
/// matching `jnp.fft.fft` / `torch.fft.fft`).
pub fn fft<S: Scalar>(x: &mut [Cplx<S>]) {
    let n = x.len();
    if n <= 1 {
        return;
    }
    if n.is_power_of_two() {
        radix2(x, false);
    } else {
        bluestein(x, false);
    }
}

/// Inverse DFT with 1/n normalization.
pub fn ifft<S: Scalar>(x: &mut [Cplx<S>]) {
    let n = x.len();
    if n <= 1 {
        return;
    }
    if n.is_power_of_two() {
        radix2(x, true);
    } else {
        bluestein(x, true);
    }
    let inv = S::from_f64(1.0 / n as f64);
    for z in x.iter_mut() {
        *z = z.scale(inv);
    }
}

/// Naive O(n²) DFT — oracle for tests and for the theory module's
/// per-frequency error measurements (it evaluates a single ω cheaply).
pub fn dft_naive<S: Scalar>(x: &[Cplx<S>]) -> Vec<Cplx<S>> {
    let n = x.len();
    let mut out = Vec::with_capacity(n);
    for k in 0..n {
        let mut acc = Cplx::<S>::zero();
        for (j, &v) in x.iter().enumerate() {
            let theta = -2.0 * std::f64::consts::PI * (j as f64) * (k as f64) / n as f64;
            acc = acc.add(v.mul(Cplx::cis(theta)));
        }
        out.push(acc);
    }
    out
}

/// Single DFT coefficient at integer frequency `k` (used by theory module).
pub fn dft_coeff<S: Scalar>(x: &[Cplx<S>], k: i64) -> Cplx<S> {
    let n = x.len();
    let mut acc = Cplx::<S>::zero();
    for (j, &v) in x.iter().enumerate() {
        let theta = -2.0 * std::f64::consts::PI * (j as f64) * (k as f64) / n as f64;
        acc = acc.add(v.mul(Cplx::cis(theta)));
    }
    acc
}

fn radix2<S: Scalar>(x: &mut [Cplx<S>], inverse: bool) {
    let n = x.len();
    debug_assert!(n.is_power_of_two());
    // Bit-reversal permutation.
    let mut j = 0usize;
    for i in 1..n {
        let mut bit = n >> 1;
        while j & bit != 0 {
            j ^= bit;
            bit >>= 1;
        }
        j |= bit;
        if i < j {
            x.swap(i, j);
        }
    }
    let sign = if inverse { 1.0 } else { -1.0 };
    let mut len = 2usize;
    while len <= n {
        let ang = sign * 2.0 * std::f64::consts::PI / len as f64;
        let half = len / 2;
        for start in (0..n).step_by(len) {
            for k in 0..half {
                // Twiddles from f64 (precomputed-table model); butterfly
                // arithmetic rounds in S.
                let w = Cplx::<S>::cis(ang * k as f64);
                let u = x[start + k];
                let v = x[start + k + half].mul(w);
                x[start + k] = u.add(v);
                x[start + k + half] = u.sub(v);
            }
        }
        len <<= 1;
    }
}

/// Bluestein chirp-z: DFT of arbitrary n via a cyclic convolution of size
/// m = next_pow2(2n-1).
fn bluestein<S: Scalar>(x: &mut [Cplx<S>], inverse: bool) {
    let n = x.len();
    let sign = if inverse { 1.0 } else { -1.0 };
    let m = (2 * n - 1).next_power_of_two();
    // a[j] = x[j] * w^{j^2/2}, b[j] = w^{-j^2/2} (chirps).
    let chirp = |j: usize| -> Cplx<S> {
        // j^2 mod 2n to keep the angle small & exact.
        let jj = ((j as u128 * j as u128) % (2 * n as u128)) as f64;
        Cplx::cis(sign * std::f64::consts::PI * jj / n as f64)
    };
    let mut a = vec![Cplx::<S>::zero(); m];
    let mut b = vec![Cplx::<S>::zero(); m];
    for j in 0..n {
        // One cis evaluation per j: a takes the chirp, b its conjugate.
        let c = chirp(j);
        a[j] = x[j].mul(c);
        let cc = c.conj();
        b[j] = cc;
        if j > 0 {
            b[m - j] = cc;
        }
    }
    radix2(&mut a, false);
    radix2(&mut b, false);
    for (av, bv) in a.iter_mut().zip(&b) {
        *av = av.mul(*bv);
    }
    radix2(&mut a, true);
    let inv_m = S::from_f64(1.0 / m as f64);
    for (k, out) in x.iter_mut().enumerate() {
        *out = a[k].scale(inv_m).mul(chirp(k));
    }
}

/// 2-D FFT over a row-major (h, w) buffer: rows then columns.
pub fn fft2<S: Scalar>(data: &mut [Cplx<S>], h: usize, w: usize) {
    assert_eq!(data.len(), h * w);
    for r in 0..h {
        fft(&mut data[r * w..(r + 1) * w]);
    }
    let mut col = vec![Cplx::<S>::zero(); h];
    for c in 0..w {
        for r in 0..h {
            col[r] = data[r * w + c];
        }
        fft(&mut col);
        for r in 0..h {
            data[r * w + c] = col[r];
        }
    }
}

/// 2-D inverse FFT (normalized by 1/(h·w) via the 1-D ifft passes).
pub fn ifft2<S: Scalar>(data: &mut [Cplx<S>], h: usize, w: usize) {
    assert_eq!(data.len(), h * w);
    for r in 0..h {
        ifft(&mut data[r * w..(r + 1) * w]);
    }
    let mut col = vec![Cplx::<S>::zero(); h];
    for c in 0..w {
        for r in 0..h {
            col[r] = data[r * w + c];
        }
        ifft(&mut col);
        for r in 0..h {
            data[r * w + c] = col[r];
        }
    }
}

/// 3-D FFT over a row-major (d, h, w) buffer: per-slab 2-D pass, then
/// lines along the leading axis.
pub fn fft3<S: Scalar>(data: &mut [Cplx<S>], d: usize, h: usize, w: usize) {
    fft3_serial(data, d, h, w, false);
}

/// 3-D inverse FFT (normalized by 1/(d·h·w) via the 1-D ifft passes).
pub fn ifft3<S: Scalar>(data: &mut [Cplx<S>], d: usize, h: usize, w: usize) {
    fft3_serial(data, d, h, w, true);
}

fn fft3_serial<S: Scalar>(data: &mut [Cplx<S>], d: usize, h: usize, w: usize, inverse: bool) {
    assert_eq!(data.len(), d * h * w);
    let slab = h * w;
    for z in 0..d {
        if inverse {
            ifft2(&mut data[z * slab..(z + 1) * slab], h, w);
        } else {
            fft2(&mut data[z * slab..(z + 1) * slab], h, w);
        }
    }
    let mut line = vec![Cplx::<S>::zero(); d];
    for rc in 0..slab {
        for z in 0..d {
            line[z] = data[z * slab + rc];
        }
        if inverse {
            ifft(&mut line);
        } else {
            fft(&mut line);
        }
        for z in 0..d {
            data[z * slab + rc] = line[z];
        }
    }
}

// ---- parallel drivers -----------------------------------------------------

/// Batched independent 1-D forward FFTs: `data` holds contiguous length-`n`
/// signals, each transformed in place, fanned over `ex`.
pub fn fft_batch<S: Scalar>(data: &mut [Cplx<S>], n: usize, ex: &Executor) {
    assert!(n > 0 && data.len() % n == 0, "buffer not a multiple of n={n}");
    ex.for_each_chunk(data, n, |_, row| fft(row));
}

/// Batched independent 1-D inverse FFTs (see [`fft_batch`]).
pub fn ifft_batch<S: Scalar>(data: &mut [Cplx<S>], n: usize, ex: &Executor) {
    assert!(n > 0 && data.len() % n == 0, "buffer not a multiple of n={n}");
    ex.for_each_chunk(data, n, |_, row| ifft(row));
}

/// 2-D FFT with the row and column passes fanned over `ex`. The column
/// pass runs on a transposed scratch buffer so each 1-D transform is a
/// contiguous chunk (better locality than the serial strided gather, same
/// arithmetic per transform).
pub fn fft2_with<S: Scalar>(data: &mut [Cplx<S>], h: usize, w: usize, ex: &Executor) {
    fft2_passes(data, h, w, ex, false);
}

/// 2-D inverse FFT over `ex` (see [`fft2_with`]).
pub fn ifft2_with<S: Scalar>(data: &mut [Cplx<S>], h: usize, w: usize, ex: &Executor) {
    fft2_passes(data, h, w, ex, true);
}

fn fft2_passes<S: Scalar>(data: &mut [Cplx<S>], h: usize, w: usize, ex: &Executor, inverse: bool) {
    assert_eq!(data.len(), h * w);
    let one_d: fn(&mut [Cplx<S>]) = if inverse { ifft } else { fft };
    // Row pass: h independent contiguous transforms.
    ex.for_each_chunk(data, w, |_, row| one_d(row));
    // Column pass: gather column c into scratch row c, transform, scatter.
    let mut scratch = vec![Cplx::<S>::zero(); h * w];
    {
        let src: &[Cplx<S>] = data;
        ex.for_each_chunk(&mut scratch, h, |c, col| {
            for (r, v) in col.iter_mut().enumerate() {
                *v = src[r * w + c];
            }
            one_d(col);
        });
    }
    let src: &[Cplx<S>] = &scratch;
    ex.for_each_chunk(data, w, |r, row| {
        for (c, v) in row.iter_mut().enumerate() {
            *v = src[c * h + r];
        }
    });
}

/// Batch of independent 2-D forward FFTs over contiguous (h, w) samples,
/// one sample per work item — the shape of the FNO spectral layer's input,
/// and the highest-leverage parallel grain (no per-pass synchronization).
pub fn fft2_batch<S: Scalar>(data: &mut [Cplx<S>], h: usize, w: usize, ex: &Executor) {
    let slab = h * w;
    assert!(slab > 0 && data.len() % slab == 0, "buffer not a multiple of h*w");
    ex.for_each_chunk(data, slab, |_, sample| fft2(sample, h, w));
}

/// Batch of independent 2-D inverse FFTs (see [`fft2_batch`]).
pub fn ifft2_batch<S: Scalar>(data: &mut [Cplx<S>], h: usize, w: usize, ex: &Executor) {
    let slab = h * w;
    assert!(slab > 0 && data.len() % slab == 0, "buffer not a multiple of h*w");
    ex.for_each_chunk(data, slab, |_, sample| ifft2(sample, h, w));
}

/// 3-D FFT with the slab and line passes fanned over `ex`.
pub fn fft3_with<S: Scalar>(data: &mut [Cplx<S>], d: usize, h: usize, w: usize, ex: &Executor) {
    fft3_passes(data, d, h, w, ex, false);
}

/// 3-D inverse FFT over `ex` (see [`fft3_with`]).
pub fn ifft3_with<S: Scalar>(data: &mut [Cplx<S>], d: usize, h: usize, w: usize, ex: &Executor) {
    fft3_passes(data, d, h, w, ex, true);
}

fn fft3_passes<S: Scalar>(
    data: &mut [Cplx<S>],
    d: usize,
    h: usize,
    w: usize,
    ex: &Executor,
    inverse: bool,
) {
    assert_eq!(data.len(), d * h * w);
    let slab = h * w;
    let one_d: fn(&mut [Cplx<S>]) = if inverse { ifft } else { fft };
    let two_d: fn(&mut [Cplx<S>], usize, usize) = if inverse { ifft2 } else { fft2 };
    // Slab pass: d independent 2-D transforms.
    ex.for_each_chunk(data, slab, |_, s| two_d(s, h, w));
    // Leading-axis pass: h*w independent length-d lines via scratch.
    let mut scratch = vec![Cplx::<S>::zero(); d * slab];
    {
        let src: &[Cplx<S>] = data;
        ex.for_each_chunk(&mut scratch, d, |rc, line| {
            for (z, v) in line.iter_mut().enumerate() {
                *v = src[z * slab + rc];
            }
            one_d(line);
        });
    }
    let src: &[Cplx<S>] = &scratch;
    ex.for_each_chunk(data, slab, |z, s| {
        for (rc, v) in s.iter_mut().enumerate() {
            *v = src[rc * d + z];
        }
    });
}

/// Real forward FFT: returns the full complex spectrum of a real signal.
pub fn rfft<S: Scalar>(x: &[f64]) -> Vec<Cplx<S>> {
    let mut z: Vec<Cplx<S>> = x.iter().map(|&v| Cplx::from_f64(v, 0.0)).collect();
    fft(&mut z);
    z
}

/// Power spectrum |X[k]|².
pub fn power_spectrum<S: Scalar>(x: &[Cplx<S>]) -> Vec<f64> {
    x.iter().map(|z| z.norm_sqr()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fp::F16;
    use crate::rng::Rng;

    fn assert_close(a: &[Cplx<f64>], b: &[Cplx<f64>], tol: f64) {
        assert_eq!(a.len(), b.len());
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            assert!(
                x.sub(*y).abs() < tol,
                "idx {i}: {:?} vs {:?}",
                x.to_f64(),
                y.to_f64()
            );
        }
    }

    fn random_signal(n: usize, seed: u64) -> Vec<Cplx<f64>> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| { let (r, i) = rng.cnormal(); Cplx::from_f64(r, i) }).collect()
    }

    #[test]
    fn fft_matches_naive_pow2() {
        for n in [2usize, 4, 8, 64, 256] {
            let x = random_signal(n, n as u64);
            let want = dft_naive(&x);
            let mut got = x.clone();
            fft(&mut got);
            assert_close(&got, &want, 1e-9 * n as f64);
        }
    }

    #[test]
    fn fft_matches_naive_nonpow2() {
        for n in [3usize, 5, 6, 7, 12, 100, 243] {
            let x = random_signal(n, n as u64);
            let want = dft_naive(&x);
            let mut got = x.clone();
            fft(&mut got);
            assert_close(&got, &want, 1e-8 * n as f64);
        }
    }

    #[test]
    fn roundtrip_identity() {
        for n in [8usize, 15, 128, 60] {
            let x = random_signal(n, 1000 + n as u64);
            let mut y = x.clone();
            fft(&mut y);
            ifft(&mut y);
            assert_close(&y, &x, 1e-10 * n as f64);
        }
    }

    #[test]
    fn parseval() {
        let n = 128;
        let x = random_signal(n, 5);
        let time_energy: f64 = x.iter().map(|z| z.norm_sqr()).sum();
        let mut y = x.clone();
        fft(&mut y);
        let freq_energy: f64 = y.iter().map(|z| z.norm_sqr()).sum::<f64>() / n as f64;
        assert!((time_energy - freq_energy).abs() / time_energy < 1e-12);
    }

    #[test]
    fn pure_tone_lands_on_one_bin() {
        let n = 64usize;
        let k0 = 5;
        let x: Vec<Cplx<f64>> = (0..n)
            .map(|j| Cplx::cis(2.0 * std::f64::consts::PI * (k0 * j) as f64 / n as f64))
            .collect();
        let mut y = x.clone();
        fft(&mut y);
        for (k, z) in y.iter().enumerate() {
            if k == k0 {
                assert!((z.abs() - n as f64).abs() < 1e-9);
            } else {
                assert!(z.abs() < 1e-9, "leakage at bin {k}: {}", z.abs());
            }
        }
    }

    #[test]
    fn fft2_separable_matches_double_naive() {
        let (h, w) = (4usize, 8usize);
        let x = random_signal(h * w, 77);
        let mut got = x.clone();
        fft2(&mut got, h, w);
        // Naive 2-D: DFT rows then DFT cols.
        let mut want = x.clone();
        for r in 0..h {
            let row = dft_naive(&want[r * w..(r + 1) * w]);
            want[r * w..(r + 1) * w].copy_from_slice(&row);
        }
        for c in 0..w {
            let col: Vec<_> = (0..h).map(|r| want[r * w + c]).collect();
            let colf = dft_naive(&col);
            for r in 0..h {
                want[r * w + c] = colf[r];
            }
        }
        assert_close(&got, &want, 1e-9 * (h * w) as f64);
    }

    #[test]
    fn fft2_roundtrip() {
        let (h, w) = (8usize, 8usize);
        let x = random_signal(h * w, 9);
        let mut y = x.clone();
        fft2(&mut y, h, w);
        ifft2(&mut y, h, w);
        assert_close(&y, &x, 1e-10 * (h * w) as f64);
    }

    #[test]
    fn half_precision_fft_error_is_epsilon_scale() {
        // Theorem 3.2's message made concrete: a unit-scale signal's
        // fp16 FFT deviates at the ~1e-3 relative level, not catastrophically.
        let n = 256;
        let xs = random_signal(n, 21);
        let mut ref64 = xs.clone();
        fft(&mut ref64);
        let xh: Vec<Cplx<F16>> = xs.iter().map(|z| z.cast()).collect();
        let mut got = xh.clone();
        fft(&mut got);
        let mut num = 0.0;
        let mut den = 0.0;
        for (g, r) in got.iter().zip(&ref64) {
            let g64: Cplx<f64> = g.cast();
            num += g64.sub(*r).norm_sqr();
            den += r.norm_sqr();
        }
        let rel = (num / den).sqrt();
        assert!(rel < 0.02, "rel={rel}");
        assert!(rel > 1e-5, "half precision should be visibly lossy: rel={rel}");
    }

    #[test]
    fn half_precision_fft_overflows_on_large_inputs() {
        // The §4.3 failure mode: inputs ~3e4 overflow 65504 inside the
        // butterflies -> non-finite outputs. tanh pre-activation fixes this
        // by bounding |v| <= 1.
        let n = 64;
        let mut big: Vec<Cplx<F16>> =
            (0..n).map(|_| Cplx::from_f64(30000.0, 0.0)).collect();
        fft(&mut big);
        assert!(big.iter().any(|z| !z.is_finite()));

        let mut tanh_stab: Vec<Cplx<F16>> =
            (0..n).map(|_| Cplx::from_f64(30000.0_f64.tanh(), 0.0)).collect();
        fft(&mut tanh_stab);
        assert!(tanh_stab.iter().all(|z| z.is_finite()));
    }

    #[test]
    fn fft3_separable_matches_1d_composition() {
        // fft3 == DFT along w, then h, then d (any order — transforms on
        // distinct axes commute). Build the oracle from dft_naive lines.
        let (d, h, w) = (3usize, 4, 5);
        let x = random_signal(d * h * w, 123);
        let mut got = x.clone();
        fft3(&mut got, d, h, w);
        let mut want = x;
        for z in 0..d {
            for r in 0..h {
                let o = z * h * w + r * w;
                let row = dft_naive(&want[o..o + w]);
                want[o..o + w].copy_from_slice(&row);
            }
        }
        for z in 0..d {
            for c in 0..w {
                let col: Vec<_> = (0..h).map(|r| want[z * h * w + r * w + c]).collect();
                let colf = dft_naive(&col);
                for r in 0..h {
                    want[z * h * w + r * w + c] = colf[r];
                }
            }
        }
        for rc in 0..h * w {
            let line: Vec<_> = (0..d).map(|z| want[z * h * w + rc]).collect();
            let linef = dft_naive(&line);
            for z in 0..d {
                want[z * h * w + rc] = linef[z];
            }
        }
        assert_close(&got, &want, 1e-9 * (d * h * w) as f64);
    }

    #[test]
    fn fft3_roundtrip() {
        let (d, h, w) = (4usize, 6, 8);
        let x = random_signal(d * h * w, 31);
        let mut y = x.clone();
        fft3(&mut y, d, h, w);
        ifft3(&mut y, d, h, w);
        assert_close(&y, &x, 1e-10 * (d * h * w) as f64);
    }

    #[test]
    fn parallel_drivers_match_serial() {
        // Shapes exceed parallel::MIN_PARALLEL_ELEMS so workers engage.
        use crate::parallel::Executor;
        let (h, w) = (24usize, 32);
        let x = random_signal(h * w, 55);
        let mut want2 = x.clone();
        fft2(&mut want2, h, w);
        for threads in [1usize, 2, 8] {
            let ex = Executor::new(threads);
            let mut got = x.clone();
            fft2_with(&mut got, h, w, &ex);
            assert_close(&got, &want2, 1e-12);
            ifft2_with(&mut got, h, w, &ex);
            assert_close(&got, &x, 1e-12);
        }
        let (d, h, w) = (4usize, 8, 16);
        let x3 = random_signal(d * h * w, 56);
        let mut want3 = x3.clone();
        fft3(&mut want3, d, h, w);
        for threads in [1usize, 2, 8] {
            let mut got = x3.clone();
            fft3_with(&mut got, d, h, w, &Executor::new(threads));
            assert_close(&got, &want3, 1e-12);
        }
    }

    #[test]
    fn batched_drivers_match_per_sample_serial() {
        use crate::parallel::Executor;
        let (b, n) = (8usize, 64);
        let x = random_signal(b * n, 77);
        let mut want = x.clone();
        for i in 0..b {
            fft(&mut want[i * n..(i + 1) * n]);
        }
        let ex = Executor::new(8);
        let mut got = x.clone();
        fft_batch(&mut got, n, &ex);
        assert_close(&got, &want, 1e-12);
        ifft_batch(&mut got, n, &ex);
        assert_close(&got, &x, 1e-12 * n as f64);

        let (b, h, w) = (6usize, 8, 12);
        let x2 = random_signal(b * h * w, 78);
        let mut want2 = x2.clone();
        for i in 0..b {
            fft2(&mut want2[i * h * w..(i + 1) * h * w], h, w);
        }
        let mut got2 = x2.clone();
        fft2_batch(&mut got2, h, w, &ex);
        assert_close(&got2, &want2, 1e-12);
        ifft2_batch(&mut got2, h, w, &ex);
        assert_close(&got2, &x2, 1e-12 * (h * w) as f64);
    }

    #[test]
    fn dft_coeff_matches_naive() {
        let x = random_signal(17, 3);
        let full = dft_naive(&x);
        for k in 0..17 {
            let c = dft_coeff(&x, k as i64);
            assert!(c.sub(full[k]).abs() < 1e-10);
        }
    }
}
