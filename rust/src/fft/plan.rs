//! Planned FFTs: per-(n, direction) cached twiddle tables, bit-reversal
//! permutations and Bluestein chirp/b-spectra.
//!
//! # Why planned results are bit-identical to the ad-hoc kernels
//!
//! The serial kernels in [`super`] derive every constant from the same
//! f64 formula — `Cplx::<S>::cis(theta)` evaluates `cos`/`sin` in f64 and
//! rounds *once* into `S` (the precomputed-table model of real FFT
//! libraries). A [`Plan`] evaluates exactly those formulas, at exactly the
//! same `theta` arguments, once at construction instead of once per
//! butterfly per call. The butterfly/convolution arithmetic then consumes
//! the cached values in the same order as the ad-hoc kernel, so every
//! output element sees the *same sequence of rounded operations* at every
//! [`Scalar`] precision and the results are bit-identical (enforced by
//! `tests/spectral_parity.rs`). Concretely:
//!
//! * radix-2 twiddles: `cis(sign·2π/len · k)` for each stage length
//!   `len` and `k < len/2` — cached flat with stage offset `len/2 − 1`;
//! * the bit-reversal permutation — a pure index table;
//! * Bluestein: the chirp `cis(sign·π·(j² mod 2n)/n)`, its conjugate
//!   padded into the length-`m` kernel, and that kernel's forward
//!   spectrum (computed once *in `S`* by the same cached-twiddle radix-2,
//!   so it matches the per-call `radix2(&mut b, false)` of the ad-hoc
//!   path bit-for-bit).
//!
//! Plans are immutable after construction and shared via `Arc`; a global
//! per-precision cache ([`plan_for`]) memoizes them by (n, direction).
//! Hot paths (the fused spectral engine, truncated 2-D passes, spectral
//! resampling) hold their plans directly so the cache lock is off the
//! per-transform path.

use crate::fp::lanes;
use crate::fp::{Cplx, Scalar};
use std::any::{Any, TypeId};
use std::collections::HashMap;
use std::sync::{Arc, Mutex, OnceLock};

/// Cached tables for an in-place radix-2 transform of power-of-two size.
#[derive(Debug)]
pub(crate) struct RadixTables<S: Scalar> {
    n: usize,
    /// `bitrev[i]` = bit-reversed index of `i`; applied as `swap(i, bitrev[i])`
    /// for `i < bitrev[i]`, matching the serial kernel's incremental loop.
    bitrev: Vec<u32>,
    /// Stage twiddles, flattened: stage of length `len` starts at
    /// `len/2 − 1` and holds `len/2` entries `cis(sign·2π·k/len)`.
    twiddles: Vec<Cplx<S>>,
}

impl<S: Scalar> RadixTables<S> {
    fn new(n: usize, inverse: bool) -> Self {
        debug_assert!(n.is_power_of_two());
        // Same incremental bit-reversal walk as the serial kernel.
        let mut bitrev = vec![0u32; n];
        let mut j = 0usize;
        for i in 1..n {
            let mut bit = n >> 1;
            while j & bit != 0 {
                j ^= bit;
                bit >>= 1;
            }
            j |= bit;
            bitrev[i] = j as u32;
        }
        let sign = if inverse { 1.0 } else { -1.0 };
        let mut twiddles = Vec::with_capacity(n.saturating_sub(1));
        let mut len = 2usize;
        while len <= n {
            let ang = sign * 2.0 * std::f64::consts::PI / len as f64;
            for k in 0..len / 2 {
                twiddles.push(Cplx::<S>::cis(ang * k as f64));
            }
            len <<= 1;
        }
        RadixTables { n, bitrev, twiddles }
    }

    /// In-place radix-2 pass from cached tables — the same operation
    /// sequence as the serial `radix2`, with table lookups replacing the
    /// per-butterfly `cis` evaluation.
    fn apply(&self, x: &mut [Cplx<S>]) {
        let n = self.n;
        debug_assert_eq!(x.len(), n);
        for i in 1..n {
            let j = self.bitrev[i] as usize;
            if i < j {
                x.swap(i, j);
            }
        }
        let mut len = 2usize;
        while len <= n {
            let half = len / 2;
            let tw = &self.twiddles[half - 1..half - 1 + half];
            for start in (0..n).step_by(len) {
                // Stride-1 butterfly row via the lane kernel — the same
                // u.add(v)/u.sub(v) sequence per k, unrolled.
                let (lo, hi) = x[start..start + len].split_at_mut(half);
                lanes::cbutterfly(lo, hi, tw);
            }
            len <<= 1;
        }
    }
}

/// Bluestein chirp-z tables for an arbitrary size `n`.
#[derive(Debug)]
struct BluesteinTables<S: Scalar> {
    /// Convolution size: next power of two ≥ 2n−1.
    m: usize,
    /// `chirp[j] = cis(sign·π·(j² mod 2n)/n)` for `j < n`.
    chirp: Vec<Cplx<S>>,
    /// Forward spectrum of the padded conjugate-chirp kernel, computed in
    /// `S` by the cached-twiddle radix-2 — identical to the ad-hoc path's
    /// per-call `radix2(&mut b, false)`.
    b_spec: Vec<Cplx<S>>,
    m_fwd: RadixTables<S>,
    m_inv: RadixTables<S>,
}

impl<S: Scalar> BluesteinTables<S> {
    fn new(n: usize, inverse: bool) -> Self {
        let sign = if inverse { 1.0 } else { -1.0 };
        let m = (2 * n - 1).next_power_of_two();
        let chirp: Vec<Cplx<S>> = (0..n)
            .map(|j| {
                // j² mod 2n keeps the angle small & exact (as in the
                // serial kernel).
                let jj = ((j as u128 * j as u128) % (2 * n as u128)) as f64;
                Cplx::cis(sign * std::f64::consts::PI * jj / n as f64)
            })
            .collect();
        let m_fwd = RadixTables::new(m, false);
        let m_inv = RadixTables::new(m, true);
        let mut b = vec![Cplx::<S>::zero(); m];
        for (j, c) in chirp.iter().enumerate() {
            let cc = c.conj();
            b[j] = cc;
            if j > 0 {
                b[m - j] = cc;
            }
        }
        m_fwd.apply(&mut b);
        BluesteinTables { m, chirp, b_spec: b, m_fwd, m_inv }
    }
}

#[derive(Debug)]
enum PlanKind<S: Scalar> {
    /// n ≤ 1: identity.
    Tiny,
    Radix2(RadixTables<S>),
    Bluestein(Box<BluesteinTables<S>>),
}

/// A reusable 1-D DFT plan for one (size, direction) pair at precision `S`.
///
/// Invariant: applying a plan is bit-identical to the ad-hoc serial
/// [`super::fft`] / [`super::ifft`] at every `Scalar` precision (see the
/// module docs for why).
#[derive(Debug)]
pub struct Plan<S: Scalar> {
    n: usize,
    inverse: bool,
    kind: PlanKind<S>,
}

impl<S: Scalar> Plan<S> {
    /// Build a forward-DFT plan of size `n`.
    pub fn forward(n: usize) -> Plan<S> {
        Plan::new(n, false)
    }

    /// Build an inverse-DFT plan of size `n` (1/n-normalized, like
    /// [`super::ifft`]).
    pub fn inverse(n: usize) -> Plan<S> {
        Plan::new(n, true)
    }

    fn new(n: usize, inverse: bool) -> Plan<S> {
        let kind = if n <= 1 {
            PlanKind::Tiny
        } else if n.is_power_of_two() {
            PlanKind::Radix2(RadixTables::new(n, inverse))
        } else {
            PlanKind::Bluestein(Box::new(BluesteinTables::new(n, inverse)))
        };
        Plan { n, inverse, kind }
    }

    pub fn len(&self) -> usize {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    pub fn is_inverse(&self) -> bool {
        self.inverse
    }

    /// Scratch length [`Plan::apply`] needs (0 unless Bluestein).
    pub fn scratch_len(&self) -> usize {
        match &self.kind {
            PlanKind::Bluestein(b) => b.m,
            _ => 0,
        }
    }

    /// Transform `x` in place. `scratch` is grown to [`Plan::scratch_len`]
    /// on demand and never shrunk, so a caller looping over many
    /// transforms allocates once.
    pub fn apply(&self, x: &mut [Cplx<S>], scratch: &mut Vec<Cplx<S>>) {
        assert_eq!(x.len(), self.n, "plan is for n={}, got {}", self.n, x.len());
        match &self.kind {
            PlanKind::Tiny => {}
            PlanKind::Radix2(t) => t.apply(x),
            PlanKind::Bluestein(b) => {
                let n = self.n;
                let m = b.m;
                if scratch.len() < m {
                    scratch.resize(m, Cplx::zero());
                }
                let a = &mut scratch[..m];
                lanes::vfill(&mut a[n..], Cplx::zero());
                lanes::cmul_into(&mut a[..n], x, &b.chirp);
                b.m_fwd.apply(a);
                lanes::cmul_assign(a, &b.b_spec);
                b.m_inv.apply(a);
                let inv_m = S::from_f64(1.0 / m as f64);
                lanes::cscale_mul_into(x, &a[..n], inv_m, &b.chirp);
            }
        }
        if self.inverse && self.n > 1 {
            let inv = S::from_f64(1.0 / self.n as f64);
            lanes::cscale_assign(x, inv);
        }
    }

    /// Convenience wrapper that allocates its own scratch.
    pub fn apply_alloc(&self, x: &mut [Cplx<S>]) {
        let mut scratch = Vec::new();
        self.apply(x, &mut scratch);
    }
}

/// Global per-precision plan cache keyed by (n, direction). Used by entry
/// points without a natural place to store plans (e.g. spectral
/// resampling); long-lived engines hold their `Arc<Plan>` directly.
fn cache() -> &'static Mutex<HashMap<(TypeId, usize, bool), Arc<dyn Any + Send + Sync>>> {
    static CACHE: OnceLock<Mutex<HashMap<(TypeId, usize, bool), Arc<dyn Any + Send + Sync>>>> =
        OnceLock::new();
    CACHE.get_or_init(|| Mutex::new(HashMap::new()))
}

/// Memoized [`Plan`] lookup: builds the plan on first use of each
/// (precision, n, direction) triple, then returns the shared copy.
pub fn plan_for<S: Scalar>(n: usize, inverse: bool) -> Arc<Plan<S>> {
    let key = (TypeId::of::<S>(), n, inverse);
    if let Some(hit) = cache().lock().expect("plan cache poisoned").get(&key).cloned() {
        return match hit.downcast::<Plan<S>>() {
            Ok(p) => p,
            Err(_) => unreachable!("plan cache type confusion"),
        };
    }
    // Build outside the lock: a Bluestein plan costs a kernel FFT, and
    // holding the global mutex through it would serialize every other
    // first-use caller. Racing duplicate builds are harmless — the
    // first insert wins and losers drop their copy (plans of the same
    // key are identical by construction).
    let built = Arc::new(Plan::<S>::new(n, inverse));
    let mut map = cache().lock().expect("plan cache poisoned");
    let entry =
        map.entry(key).or_insert_with(|| built as Arc<dyn Any + Send + Sync>);
    match entry.clone().downcast::<Plan<S>>() {
        Ok(p) => p,
        Err(_) => unreachable!("plan cache type confusion"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fft::{fft, ifft};
    use crate::fp::{Bf16, F16};
    use crate::rng::Rng;

    fn signal<S: Scalar>(n: usize, seed: u64) -> Vec<Cplx<S>> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|_| {
                let (r, i) = rng.cnormal();
                Cplx::from_f64(r, i)
            })
            .collect()
    }

    fn bit_identical<S: Scalar>(a: &[Cplx<S>], b: &[Cplx<S>]) -> bool {
        a.len() == b.len()
            && a.iter().zip(b).all(|(x, y)| x.to_f64() == y.to_f64())
    }

    fn planned_matches_adhoc<S: Scalar>(n: usize, seed: u64) {
        let x: Vec<Cplx<S>> = signal(n, seed);
        let mut want = x.clone();
        fft(&mut want);
        let mut got = x.clone();
        Plan::<S>::forward(n).apply_alloc(&mut got);
        assert!(bit_identical(&got, &want), "fwd n={n} {}", S::name());

        let mut want_inv = x.clone();
        ifft(&mut want_inv);
        let mut got_inv = x.clone();
        Plan::<S>::inverse(n).apply_alloc(&mut got_inv);
        assert!(bit_identical(&got_inv, &want_inv), "inv n={n} {}", S::name());
    }

    #[test]
    fn planned_fft_bit_identical_to_adhoc_all_precisions() {
        // Radix-2 and Bluestein sizes, forward and inverse.
        for n in [1usize, 2, 4, 8, 64, 128, 3, 5, 12, 100, 243] {
            planned_matches_adhoc::<f64>(n, 7 + n as u64);
            planned_matches_adhoc::<f32>(n, 7 + n as u64);
            planned_matches_adhoc::<Bf16>(n, 7 + n as u64);
            planned_matches_adhoc::<F16>(n, 7 + n as u64);
        }
    }

    #[test]
    fn plan_reuse_is_deterministic() {
        let n = 60;
        let x: Vec<Cplx<f64>> = signal(n, 3);
        let plan = Plan::<f64>::forward(n);
        let mut scratch = Vec::new();
        let mut a = x.clone();
        plan.apply(&mut a, &mut scratch);
        let mut b = x.clone();
        plan.apply(&mut b, &mut scratch);
        assert!(bit_identical(&a, &b));
        assert!(scratch.len() >= plan.scratch_len());
    }

    #[test]
    fn cache_returns_shared_plans() {
        let a = plan_for::<f64>(48, false);
        let b = plan_for::<f64>(48, false);
        assert!(Arc::ptr_eq(&a, &b));
        let inv = plan_for::<f64>(48, true);
        assert!(!Arc::ptr_eq(&a, &inv));
        let other: Arc<Plan<f32>> = plan_for::<f32>(48, false);
        assert_eq!(other.len(), 48);
    }
}
