//! Hermitian half-spectrum passes for **real** input fields.
//!
//! Every field the FNO ingests is real-valued, so its spectrum is
//! conjugate-symmetric: `F[r, w−c] = conj(F[(h−r) mod h, c])`. The full
//! kept-mode block of [`super::trunc`] therefore stores (and contracts)
//! twice the information actually present. The passes here adopt the
//! rfft2/irfft2 convention of real-FFT libraries — exploit the symmetry
//! along the **last** axis only — and keep:
//!
//! * **rows**: the `2·k_max` kept frequencies of [`super::trunc::kept_indices`]
//!   (the axis-0 transform is complex, no symmetry is exploited there);
//! * **columns**: the `k_max + 1` stored columns `0..=k_max`. Column 0 is
//!   the DC bin and always self-conjugate; column `k_max` is the Nyquist
//!   bin (self-conjugate) exactly when `2·k_max == w`, otherwise it is a
//!   genuine positive frequency whose mirror `w − k_max` is implied. The
//!   negative columns `w − k_max .. w` of the full block are never stored:
//!   they are the conjugates of stored columns `1..=k_max` row by row.
//!
//! Storage is a structure-of-arrays [`HalfSpectrum`] (split `re`/`im`
//! slices) so the mode contraction streams two flat real arrays instead
//! of interleaved pairs. Mode count per channel drops from `4·k_max²` to
//! `2·k_max·(k_max+1)` — about half for the paper's `k_max = 16`.
//!
//! # Transform definitions and parity
//!
//! [`rfft2_kept`] is the forward rfft2 restricted to the stored block:
//! a full complex row pass over the real-ified input (identical
//! arithmetic to complexifying and running the ad-hoc `fft2` row pass),
//! then column transforms of only the `k_max+1` stored columns. It is
//! bit-identical to `gather(fft2(complexify(x)))` on the stored cells —
//! the same "skip only discarded work" argument as [`super::trunc`].
//!
//! [`irfft2_kept`] is irfft2 restricted to the kept rows: inverse
//! column transforms of the stored columns (kept rows scattered into
//! zeroed lines), then per row a Hermitian extension to full width
//! (`row[w−j] = conj(row[j])`, skipping the self-conjugate DC and
//! Nyquist bins) followed by an inverse row transform, keeping the real
//! part. Note the pass order is columns-then-rows — the opposite of the
//! complex `ifft2` — because the extension must happen after the axis-0
//! inverse; the serial composed oracle in [`crate::spectral`] is built
//! from the same ad-hoc 1-D kernels in the same order, so fused and
//! composed agree bit-for-bit at every precision (the planned kernels
//! are bit-identical to the ad-hoc ones, see [`super::plan`]).
//!
//! The `*_with` variants fan the independent 1-D transforms of each pass
//! over an [`Executor`] (within-sample row/column fan-out for wide grids
//! when `batch ≪ threads`), bit-identical to the serial passes.

use super::plan::Plan;
use super::trunc::{grow, SpectralScratch};
use crate::fp::lanes;
use crate::fp::{Cplx, Scalar};
use crate::parallel::Executor;

/// Stored columns of the half-spectrum: `0..=k_max`.
pub fn half_cols(k_max: usize) -> usize {
    k_max + 1
}

/// Weight-gradient factor for stored column `j` on an axis of length
/// `w`: self-conjugate bins (DC, and Nyquist when `2·j == w`) appear
/// once in the implied full spectrum, every other stored column stands
/// for itself *and* its conjugate mirror — the "doubled-weight"
/// correction that keeps gradients exact on the halved mode set.
pub fn col_weight_factor(j: usize, w: usize) -> f64 {
    if j == 0 || 2 * j == w {
        1.0
    } else {
        2.0
    }
}

/// Structure-of-arrays half-spectrum: `channels` stacked row-major
/// (kept_rows × stored_cols) blocks with split `re`/`im` storage.
#[derive(Debug, Clone)]
pub struct HalfSpectrum<S: Scalar> {
    channels: usize,
    kr: usize,
    kc: usize,
    re: Vec<S>,
    im: Vec<S>,
}

impl<S: Scalar> Default for HalfSpectrum<S> {
    /// Empty (0-channel) placeholder a layer's `ensure_scratch` replaces
    /// on first use. A manual impl: deriving would demand `S: Default`,
    /// which the emulated formats deliberately do not provide.
    fn default() -> Self {
        HalfSpectrum { channels: 0, kr: 0, kc: 0, re: Vec::new(), im: Vec::new() }
    }
}

impl<S: Scalar> HalfSpectrum<S> {
    /// Zeroed spectrum for `channels` blocks of (kr kept rows × kc
    /// stored columns).
    pub fn zeros(channels: usize, kr: usize, kc: usize) -> Self {
        let n = channels * kr * kc;
        HalfSpectrum { channels, kr, kc, re: vec![S::zero(); n], im: vec![S::zero(); n] }
    }

    pub fn channels(&self) -> usize {
        self.channels
    }

    /// Stored modes per channel (kept_rows · stored_cols).
    pub fn n_modes(&self) -> usize {
        self.kr * self.kc
    }

    pub fn re(&self) -> &[S] {
        &self.re
    }

    pub fn im(&self) -> &[S] {
        &self.im
    }

    /// Split mutable views of the full re/im planes.
    pub fn parts_mut(&mut self) -> (&mut [S], &mut [S]) {
        (&mut self.re, &mut self.im)
    }

    /// One channel's (re, im) block.
    pub fn channel(&self, c: usize) -> (&[S], &[S]) {
        let n = self.n_modes();
        (&self.re[c * n..(c + 1) * n], &self.im[c * n..(c + 1) * n])
    }

    /// One channel's mutable (re, im) block.
    pub fn channel_mut(&mut self, c: usize) -> (&mut [S], &mut [S]) {
        let n = self.n_modes();
        (&mut self.re[c * n..(c + 1) * n], &mut self.im[c * n..(c + 1) * n])
    }

    /// Overwrite from another spectrum of identical shape (the
    /// activation-stash copy of the training tape).
    pub fn copy_from(&mut self, other: &HalfSpectrum<S>) {
        assert_eq!(self.re.len(), other.re.len(), "shape mismatch");
        self.re.copy_from_slice(&other.re);
        self.im.copy_from_slice(&other.im);
    }
}

/// Forward rfft2 of a real row-major (h, w) field onto the stored
/// half-block: full complex row pass, then column transforms of only
/// the `k_max+1` stored columns, gathered at `kept_rows` into the SoA
/// output (`out_re`/`out_im`, row-major kept_rows × (k_max+1)).
pub fn rfft2_kept<S: Scalar>(
    src: &[S],
    h: usize,
    w: usize,
    kept_rows: &[usize],
    k_max: usize,
    row_plan: &Plan<S>,
    col_plan: &Plan<S>,
    out_re: &mut [S],
    out_im: &mut [S],
    scratch: &mut SpectralScratch<S>,
) {
    let kc = half_cols(k_max);
    assert_eq!(src.len(), h * w);
    assert!(2 * k_max <= w, "2*k_max={} exceeds axis length {w}", 2 * k_max);
    assert_eq!(row_plan.len(), w, "row plan length");
    assert_eq!(col_plan.len(), h, "col plan length");
    assert!(!row_plan.is_inverse() && !col_plan.is_inverse(), "need forward plans");
    let kr = kept_rows.len();
    assert_eq!(out_re.len(), kr * kc);
    assert_eq!(out_im.len(), kr * kc);
    let SpectralScratch { rows, line, blue, .. } = scratch;
    // Row pass in full over the real-ified input: identical arithmetic
    // to complexify + fft2's row pass.
    grow(rows, h * w);
    lanes::complexify(&mut rows[..h * w], src);
    for r in 0..h {
        row_plan.apply(&mut rows[r * w..(r + 1) * w], blue);
    }
    // Column pass on the stored columns only.
    grow(line, h);
    for j in 0..kc {
        for r in 0..h {
            line[r] = rows[r * w + j];
        }
        col_plan.apply(&mut line[..h], blue);
        for (i, &r) in kept_rows.iter().enumerate() {
            let z = line[r];
            out_re[i * kc + j] = z.re;
            out_im[i * kc + j] = z.im;
        }
    }
}

/// [`rfft2_kept`] with the row and column passes fanned over `ex` —
/// bit-identical to the serial pass (see [`super::trunc::fft2_kept_with`]).
pub fn rfft2_kept_with<S: Scalar>(
    src: &[S],
    h: usize,
    w: usize,
    kept_rows: &[usize],
    k_max: usize,
    row_plan: &Plan<S>,
    col_plan: &Plan<S>,
    out_re: &mut [S],
    out_im: &mut [S],
    scratch: &mut SpectralScratch<S>,
    ex: &Executor,
) {
    let kc = half_cols(k_max);
    assert_eq!(src.len(), h * w);
    assert!(2 * k_max <= w, "2*k_max={} exceeds axis length {w}", 2 * k_max);
    assert_eq!(row_plan.len(), w, "row plan length");
    assert_eq!(col_plan.len(), h, "col plan length");
    assert!(!row_plan.is_inverse() && !col_plan.is_inverse(), "need forward plans");
    let kr = kept_rows.len();
    assert_eq!(out_re.len(), kr * kc);
    assert_eq!(out_im.len(), kr * kc);
    let SpectralScratch { rows, cols, .. } = scratch;
    grow(rows, h * w);
    ex.for_each_chunk_with(&mut rows[..h * w], w, Vec::new, |r, row, blue| {
        lanes::complexify(row, &src[r * w..(r + 1) * w]);
        row_plan.apply(row, blue);
    });
    grow(cols, kc * h);
    {
        let rows_ro: &[Cplx<S>] = rows;
        ex.for_each_chunk_with(&mut cols[..kc * h], h, Vec::new, |j, col, blue| {
            for (r, v) in col.iter_mut().enumerate() {
                *v = rows_ro[r * w + j];
            }
            col_plan.apply(col, blue);
        });
    }
    for (i, &r) in kept_rows.iter().enumerate() {
        for j in 0..kc {
            let z = cols[j * h + r];
            out_re[i * kc + j] = z.re;
            out_im[i * kc + j] = z.im;
        }
    }
}

/// Inverse of [`rfft2_kept`] back to a real (h, w) grid: inverse column
/// transforms of the stored columns (kept rows scattered into zeroed
/// lines), then per full-grid row the Hermitian extension
/// `row[w−j] = conj(row[j])` (skipping self-conjugate DC/Nyquist bins)
/// and an inverse row transform, keeping the real part.
pub fn irfft2_kept<S: Scalar>(
    spec_re: &[S],
    spec_im: &[S],
    h: usize,
    w: usize,
    kept_rows: &[usize],
    k_max: usize,
    row_inv: &Plan<S>,
    col_inv: &Plan<S>,
    out: &mut [S],
    scratch: &mut SpectralScratch<S>,
) {
    let kc = half_cols(k_max);
    let kr = kept_rows.len();
    assert_eq!(spec_re.len(), kr * kc);
    assert_eq!(spec_im.len(), kr * kc);
    assert_eq!(out.len(), h * w);
    assert!(2 * k_max <= w, "2*k_max={} exceeds axis length {w}", 2 * k_max);
    assert_eq!(row_inv.len(), w, "row plan length");
    assert_eq!(col_inv.len(), h, "col plan length");
    assert!(row_inv.is_inverse() && col_inv.is_inverse(), "need inverse plans");
    let SpectralScratch { cols, line, blue, .. } = scratch;
    // Axis-0 inverse on the stored columns only: all other columns of
    // the implied half spectrum are derived, not independent.
    grow(cols, kc * h);
    for j in 0..kc {
        let col = &mut cols[j * h..(j + 1) * h];
        lanes::vfill(col, Cplx::zero());
        for (i, &r) in kept_rows.iter().enumerate() {
            col[r] = Cplx::new(spec_re[i * kc + j], spec_im[i * kc + j]);
        }
        col_inv.apply(col, blue);
    }
    // Axis-1 inverse over every output row, Hermitian-extended to full
    // width. `w − j > k_max` excludes exactly the self-conjugate Nyquist
    // column (j = k_max with 2·k_max == w); DC is excluded by j ≥ 1.
    grow(line, w);
    for r in 0..h {
        let row = &mut line[..w];
        lanes::vfill(row, Cplx::zero());
        for j in 0..kc {
            row[j] = cols[j * h + r];
        }
        for j in 1..kc {
            let m = w - j;
            if m > k_max {
                row[m] = cols[j * h + r].conj();
            }
        }
        row_inv.apply(row, blue);
        lanes::real_part(&mut out[r * w..(r + 1) * w], row);
    }
}

/// [`irfft2_kept`] with the column and row passes fanned over `ex` —
/// bit-identical to the serial pass.
pub fn irfft2_kept_with<S: Scalar>(
    spec_re: &[S],
    spec_im: &[S],
    h: usize,
    w: usize,
    kept_rows: &[usize],
    k_max: usize,
    row_inv: &Plan<S>,
    col_inv: &Plan<S>,
    out: &mut [S],
    scratch: &mut SpectralScratch<S>,
    ex: &Executor,
) {
    let kc = half_cols(k_max);
    let kr = kept_rows.len();
    assert_eq!(spec_re.len(), kr * kc);
    assert_eq!(spec_im.len(), kr * kc);
    assert_eq!(out.len(), h * w);
    assert!(2 * k_max <= w, "2*k_max={} exceeds axis length {w}", 2 * k_max);
    assert_eq!(row_inv.len(), w, "row plan length");
    assert_eq!(col_inv.len(), h, "col plan length");
    assert!(row_inv.is_inverse() && col_inv.is_inverse(), "need inverse plans");
    let SpectralScratch { cols, .. } = scratch;
    grow(cols, kc * h);
    ex.for_each_chunk_with(&mut cols[..kc * h], h, Vec::new, |j, col, blue| {
        lanes::vfill(col, Cplx::zero());
        for (i, &r) in kept_rows.iter().enumerate() {
            col[r] = Cplx::new(spec_re[i * kc + j], spec_im[i * kc + j]);
        }
        col_inv.apply(col, blue);
    });
    let cols_ro: &[Cplx<S>] = cols;
    ex.for_each_chunk_with(
        out,
        w,
        || (vec![Cplx::<S>::zero(); w], Vec::new()),
        |r, chunk, (row, blue)| {
            lanes::vfill(row, Cplx::zero());
            for j in 0..kc {
                row[j] = cols_ro[j * h + r];
            }
            for j in 1..kc {
                let m = w - j;
                if m > k_max {
                    row[m] = cols_ro[j * h + r].conj();
                }
            }
            row_inv.apply(row, blue);
            lanes::real_part(chunk, row);
        },
    );
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fft::trunc::kept_indices;
    use crate::fft::{fft2, ifft, plan_for};
    use crate::rng::Rng;

    fn real_signal(n: usize, seed: u64) -> Vec<f64> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| rng.normal()).collect()
    }

    fn half_signal(n: usize, seed: u64) -> (Vec<f64>, Vec<f64>) {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|_| rng.cnormal())
            .unzip()
    }

    /// Serial composed forward oracle: complexify, ad-hoc full `fft2`,
    /// gather kept rows × stored columns.
    fn rfft2_oracle(src: &[f64], h: usize, w: usize, k: usize) -> (Vec<f64>, Vec<f64>) {
        let mut full: Vec<Cplx<f64>> =
            src.iter().map(|&v| Cplx::new(v, 0.0)).collect();
        fft2(&mut full, h, w);
        let kept = kept_indices(h, k);
        let kc = half_cols(k);
        let mut re = Vec::with_capacity(kept.len() * kc);
        let mut im = Vec::with_capacity(kept.len() * kc);
        for &r in &kept {
            for j in 0..kc {
                let z = full[r * w + j];
                re.push(z.re);
                im.push(z.im);
            }
        }
        (re, im)
    }

    /// Serial composed inverse oracle from ad-hoc 1-D kernels in the
    /// fused pass's order: stored-column inverse transforms, then
    /// Hermitian-extended row inverse transforms, real part.
    fn irfft2_oracle(
        sre: &[f64],
        sim: &[f64],
        h: usize,
        w: usize,
        k: usize,
    ) -> Vec<f64> {
        let kept = kept_indices(h, k);
        let kc = half_cols(k);
        let mut cols = vec![Cplx::<f64>::zero(); kc * h];
        for j in 0..kc {
            let mut line = vec![Cplx::<f64>::zero(); h];
            for (i, &r) in kept.iter().enumerate() {
                line[r] = Cplx::new(sre[i * kc + j], sim[i * kc + j]);
            }
            ifft(&mut line);
            cols[j * h..(j + 1) * h].copy_from_slice(&line);
        }
        let mut out = vec![0.0f64; h * w];
        for r in 0..h {
            let mut row = vec![Cplx::<f64>::zero(); w];
            for j in 0..kc {
                row[j] = cols[j * h + r];
            }
            for j in 1..kc {
                let m = w - j;
                if m > k {
                    row[m] = cols[j * h + r].conj();
                }
            }
            ifft(&mut row);
            for c in 0..w {
                out[r * w + c] = row[c].re;
            }
        }
        out
    }

    #[test]
    fn rfft2_matches_full_fft2_gather_bitwise() {
        // Radix-2, Bluestein, and the 2·k_max == axis boundary.
        for (h, w, k) in [(8usize, 8usize, 4usize), (16, 8, 3), (9, 15, 4), (12, 20, 5)] {
            let x = real_signal(h * w, 3 + (h * w) as u64);
            let (want_re, want_im) = rfft2_oracle(&x, h, w, k);
            let kept = kept_indices(h, k);
            let kc = half_cols(k);
            let mut got_re = vec![0.0f64; kept.len() * kc];
            let mut got_im = vec![0.0f64; kept.len() * kc];
            let mut scratch = SpectralScratch::new();
            rfft2_kept(
                &x,
                h,
                w,
                &kept,
                k,
                &plan_for::<f64>(w, false),
                &plan_for::<f64>(h, false),
                &mut got_re,
                &mut got_im,
                &mut scratch,
            );
            assert_eq!(got_re, want_re, "re h={h} w={w} k={k}");
            assert_eq!(got_im, want_im, "im h={h} w={w} k={k}");
        }
    }

    #[test]
    fn irfft2_matches_composed_1d_oracle_bitwise() {
        for (h, w, k) in [(8usize, 8usize, 4usize), (16, 8, 3), (9, 15, 4), (12, 20, 5)] {
            let kept = kept_indices(h, k);
            let kc = half_cols(k);
            let (sre, sim) = half_signal(kept.len() * kc, 11 + (h + w) as u64);
            let want = irfft2_oracle(&sre, &sim, h, w, k);
            let mut got = vec![0.0f64; h * w];
            let mut scratch = SpectralScratch::new();
            irfft2_kept(
                &sre,
                &sim,
                h,
                w,
                &kept,
                k,
                &plan_for::<f64>(w, true),
                &plan_for::<f64>(h, true),
                &mut got,
                &mut scratch,
            );
            assert_eq!(got, want, "h={h} w={w} k={k}");
        }
    }

    #[test]
    fn roundtrip_recovers_band_limited_real_fields() {
        // A real field supported on the kept band survives fwd+inv; the
        // (8, 8, 4) case puts live content in the self-conjugate Nyquist
        // column and the kept-row boundary (2·k == h == w).
        for (h, w, k) in [(16usize, 16usize, 3usize), (8, 8, 4), (12, 20, 4)] {
            let x: Vec<f64> = (0..h * w)
                .map(|i| {
                    let (r, c) = (i / w, i % w);
                    let tau = std::f64::consts::TAU;
                    (tau * (2.0 * r as f64 / h as f64)).cos()
                        + (tau * (c as f64 / w as f64)).sin()
                        + if 2 * k == w {
                            // Nyquist-mode content: alternating ±1 along w.
                            0.5 * (tau * (k as f64 * c as f64 / w as f64)).cos()
                        } else {
                            0.0
                        }
                })
                .collect();
            let kept = kept_indices(h, k);
            let kc = half_cols(k);
            let mut re = vec![0.0f64; kept.len() * kc];
            let mut im = vec![0.0f64; kept.len() * kc];
            let mut scratch = SpectralScratch::new();
            rfft2_kept(
                &x,
                h,
                w,
                &kept,
                k,
                &plan_for::<f64>(w, false),
                &plan_for::<f64>(h, false),
                &mut re,
                &mut im,
                &mut scratch,
            );
            let mut back = vec![0.0f64; h * w];
            irfft2_kept(
                &re,
                &im,
                h,
                w,
                &kept,
                k,
                &plan_for::<f64>(w, true),
                &plan_for::<f64>(h, true),
                &mut back,
                &mut scratch,
            );
            for (a, b) in back.iter().zip(&x) {
                assert!((a - b).abs() < 1e-10, "h={h} w={w} k={k}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn parallel_half_passes_match_serial_bitwise() {
        let (h, w, k) = (32usize, 40usize, 5usize);
        let kept = kept_indices(h, k);
        let kc = half_cols(k);
        let x = real_signal(h * w, 21);
        let (sre, sim) = half_signal(kept.len() * kc, 22);
        let rp = plan_for::<f64>(w, false);
        let cp = plan_for::<f64>(h, false);
        let rpi = plan_for::<f64>(w, true);
        let cpi = plan_for::<f64>(h, true);
        let mut scratch = SpectralScratch::new();
        let mut want_re = vec![0.0f64; kept.len() * kc];
        let mut want_im = vec![0.0f64; kept.len() * kc];
        rfft2_kept(&x, h, w, &kept, k, &rp, &cp, &mut want_re, &mut want_im, &mut scratch);
        let mut want_inv = vec![0.0f64; h * w];
        irfft2_kept(&sre, &sim, h, w, &kept, k, &rpi, &cpi, &mut want_inv, &mut scratch);
        for threads in [1usize, 2, 8] {
            let ex = Executor::new(threads);
            let mut gre = vec![0.0f64; want_re.len()];
            let mut gim = vec![0.0f64; want_im.len()];
            rfft2_kept_with(&x, h, w, &kept, k, &rp, &cp, &mut gre, &mut gim, &mut scratch, &ex);
            assert_eq!(gre, want_re, "fwd re threads={threads}");
            assert_eq!(gim, want_im, "fwd im threads={threads}");
            let mut ginv = vec![0.0f64; h * w];
            irfft2_kept_with(
                &sre, &sim, h, w, &kept, k, &rpi, &cpi, &mut ginv, &mut scratch, &ex,
            );
            assert_eq!(ginv, want_inv, "inv threads={threads}");
        }
    }

    #[test]
    fn col_weight_factor_self_conjugate_bins() {
        // DC always single; Nyquist single exactly at 2·j == w; every
        // other stored column implies its conjugate mirror.
        assert_eq!(col_weight_factor(0, 16), 1.0);
        assert_eq!(col_weight_factor(3, 16), 2.0);
        assert_eq!(col_weight_factor(8, 16), 1.0); // Nyquist of w=16
        assert_eq!(col_weight_factor(4, 16), 2.0);
        assert_eq!(col_weight_factor(4, 9), 2.0); // odd axis: no Nyquist
    }

    #[test]
    fn half_spectrum_layout_and_channels() {
        let mut s = HalfSpectrum::<f64>::zeros(2, 4, 3);
        assert_eq!(s.channels(), 2);
        assert_eq!(s.n_modes(), 12);
        {
            let (re, im) = s.channel_mut(1);
            re[0] = 5.0;
            im[11] = -1.0;
        }
        assert_eq!(s.re()[12], 5.0);
        assert_eq!(s.im()[23], -1.0);
        let (r0, i0) = s.channel(0);
        assert!(r0.iter().all(|&v| v == 0.0) && i0.iter().all(|&v| v == 0.0));
        let mut t = HalfSpectrum::<f64>::zeros(2, 4, 3);
        t.copy_from(&s);
        assert_eq!(t.re(), s.re());
        assert_eq!(t.im(), s.im());
    }
}
