#!/usr/bin/env bash
# Perf regression gate on BENCH_spectral.json (repo root): in every
# *recorded* section,
#   1. the fused spectral path must not be slower than the composed
#      full-FFT baseline for the same shape, and
#   2. the Hermitian half-spectrum fused path must not be slower than
#      the full-spectrum fused path at the same shape AND thread count,
#      and
#   3. batched serving must not be slower than serving the same requests
#      one at a time at the same shape AND thread count (the `serve`
#      section from bench_native; each pair times the same request set,
#      so mean_s is directly comparable). Batch-1 pairs ("... b1") do
#      identical work and are exempt — they exist to show the batching
#      overhead is flat, not to gate on noise, and
#   4. the lane (explicitly unrolled SIMD-style) SoA contraction kernels
#      must not be slower than the scalar reference kernels at the same
#      shape, precision AND thread count (paired "... reference" /
#      "... lane" rows from bench_contract and bench_native), and
#   5. serving over the loopback HTTP transport must cost at most
#      MPNO_MAX_HTTP_OVERHEAD x the in-process cost of the same requests
#      at the same shape AND thread count (paired "... direct" /
#      "... http" rows from bench_native). The bound is deliberately
#      lenient (default 50x) — it exists to catch a transport that went
#      accidentally quadratic or started re-handshaking per request, not
#      to gate syscall noise on tiny tensors.
#
# Sections suffixed `_smoke` or `_quick` hold 1-iteration CI smoke rows /
# quick-shape rows (see bench::bench_json_section) and are skipped — they
# are execution proofs, not measurements. A missing file or a file with
# only smoke/quick sections passes with a note: CI produces smoke rows on
# every run and uploads the JSON as an artifact; measurement-grade rows
# appear once `cargo bench --bench bench_fft` / `cargo bench --bench
# bench_native` / `mpno bench-par --json` run without MPNO_BENCH_SMOKE.
set -euo pipefail
cd "$(dirname "$0")/.."

BENCH_JSON="${1:-BENCH_spectral.json}"

if [ ! -f "$BENCH_JSON" ]; then
  echo "check_bench: $BENCH_JSON not present yet (no recorded rows to gate); OK"
  exit 0
fi

python3 - "$BENCH_JSON" <<'EOF'
import json
import os
import sys

path = sys.argv[1]
max_http_overhead = float(os.environ.get("MPNO_MAX_HTTP_OVERHEAD", "50"))
with open(path) as f:
    doc = json.load(f)

if not isinstance(doc, dict):
    sys.exit(f"check_bench: {path} is not a JSON object")

failures = []
checked = 0
for section, rows in sorted(doc.items()):
    if section.endswith("_smoke") or section.endswith("_quick"):
        continue
    if not isinstance(rows, list):
        continue
    # Rows are tagged "<shape> composed" / "<shape> fused" /
    # "<shape> half fused" (see SpectralBenchReport::json_rows and
    # bench_native's bench_spectral_pair). Note " half fused" also ends
    # in " fused", so classify half rows first.
    composed = {}
    fused = {}
    unbatched = {}
    reference = {}
    direct = {}
    for row in rows:
        case = row.get("case", "")
        if case.endswith(" composed"):
            composed[case[: -len(" composed")]] = row
        elif case.endswith(" fused") and not case.endswith(" half fused"):
            fused[(case[: -len(" fused")], row.get("threads"))] = row
        elif case.endswith(" unbatched"):
            unbatched[(case[: -len(" unbatched")], row.get("threads"))] = row
        elif case.endswith(" reference"):
            reference[(case[: -len(" reference")], row.get("threads"))] = row
        elif case.endswith(" direct"):
            direct[(case[: -len(" direct")], row.get("threads"))] = row
    for row in rows:
        case = row.get("case", "")
        if case.endswith(" half fused"):
            # Gate 2: half-spectrum vs full-spectrum fused, same shape
            # and thread count.
            shape = case[: -len(" half fused")]
            base = fused.get((shape, row.get("threads")))
            if base is None:
                continue
            checked += 1
            half_s, full_s = row["mean_s"], base["mean_s"]
            tag = f"{section}: {shape} (threads={row.get('threads')})"
            if half_s > full_s:
                failures.append(
                    f"{tag}: half fused {half_s:.6f}s > fused {full_s:.6f}s"
                )
            else:
                print(
                    f"check_bench: OK {tag}: half fused {half_s:.6f}s"
                    f" <= fused {full_s:.6f}s"
                )
        elif case.endswith(" fused"):
            # Gate 1: fused vs composed full-FFT baseline, same shape.
            shape = case[: -len(" fused")]
            base = composed.get(shape)
            if base is None:
                continue
            checked += 1
            fused_s, comp_s = row["mean_s"], base["mean_s"]
            tag = f"{section}: {shape} (threads={row.get('threads')})"
            if fused_s > comp_s:
                failures.append(
                    f"{tag}: fused {fused_s:.6f}s > composed {comp_s:.6f}s"
                )
            else:
                print(
                    f"check_bench: OK {tag}: fused {fused_s:.6f}s"
                    f" <= composed {comp_s:.6f}s"
                )
        elif case.endswith(" batched"):
            # Gate 3: batched serving vs one-at-a-time, same shape and
            # thread count. ("... unbatched" does not end in " batched" —
            # the char before "batched" is 'n' — so classification is
            # unambiguous.) Batch-1 pairs are identical work: skip.
            shape = case[: -len(" batched")]
            if shape.endswith(" b1"):
                continue
            base = unbatched.get((shape, row.get("threads")))
            if base is None:
                continue
            checked += 1
            bat_s, unb_s = row["mean_s"], base["mean_s"]
            tag = f"{section}: {shape} (threads={row.get('threads')})"
            if bat_s > unb_s:
                failures.append(
                    f"{tag}: batched {bat_s:.6f}s > unbatched {unb_s:.6f}s"
                )
            else:
                print(
                    f"check_bench: OK {tag}: batched {bat_s:.6f}s"
                    f" <= unbatched {unb_s:.6f}s"
                )
        elif case.endswith(" http"):
            # Gate 5: loopback HTTP vs in-process serving of the same
            # requests, same shape and thread count, bounded by a
            # lenient multiplicative overhead budget.
            shape = case[: -len(" http")]
            base = direct.get((shape, row.get("threads")))
            if base is None:
                continue
            checked += 1
            http_s, dir_s = row["mean_s"], base["mean_s"]
            tag = f"{section}: {shape} (threads={row.get('threads')})"
            if http_s > dir_s * max_http_overhead:
                failures.append(
                    f"{tag}: http {http_s:.6f}s > {max_http_overhead:g}x"
                    f" direct {dir_s:.6f}s"
                )
            else:
                print(
                    f"check_bench: OK {tag}: http {http_s:.6f}s"
                    f" <= {max_http_overhead:g}x direct {dir_s:.6f}s"
                )
        elif case.endswith(" lane"):
            # Gate 4: lane kernels vs scalar reference, same shape
            # (which encodes the precision) and thread count.
            shape = case[: -len(" lane")]
            base = reference.get((shape, row.get("threads")))
            if base is None:
                continue
            checked += 1
            lane_s, ref_s = row["mean_s"], base["mean_s"]
            tag = f"{section}: {shape} (threads={row.get('threads')})"
            if lane_s > ref_s:
                failures.append(
                    f"{tag}: lane {lane_s:.6f}s > reference {ref_s:.6f}s"
                )
            else:
                print(
                    f"check_bench: OK {tag}: lane {lane_s:.6f}s"
                    f" <= reference {ref_s:.6f}s"
                )

if failures:
    print("check_bench: A GATED PATH IS SLOWER THAN ITS BASELINE:", file=sys.stderr)
    for f_ in failures:
        print(f"  {f_}", file=sys.stderr)
    sys.exit(1)
if checked == 0:
    print("check_bench: no recorded (non-smoke, non-quick) baseline pairs yet; OK")
else:
    print(f"check_bench: {checked} recorded rows beat their baselines")
EOF
