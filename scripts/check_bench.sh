#!/usr/bin/env bash
# Perf regression gate on BENCH_spectral.json (repo root): in every
# *recorded* section, the fused spectral path must not be slower than the
# composed full-FFT baseline for the same shape.
#
# Sections suffixed `_smoke` or `_quick` hold 1-iteration CI smoke rows /
# quick-shape rows (see bench::bench_json_section) and are skipped — they
# are execution proofs, not measurements. A missing file or a file with
# only smoke/quick sections passes with a note: CI produces smoke rows on
# every run and uploads the JSON as an artifact; measurement-grade rows
# appear once `cargo bench --bench bench_fft` / `mpno bench-par --json`
# run without MPNO_BENCH_SMOKE.
set -euo pipefail
cd "$(dirname "$0")/.."

BENCH_JSON="${1:-BENCH_spectral.json}"

if [ ! -f "$BENCH_JSON" ]; then
  echo "check_bench: $BENCH_JSON not present yet (no recorded rows to gate); OK"
  exit 0
fi

python3 - "$BENCH_JSON" <<'EOF'
import json
import sys

path = sys.argv[1]
with open(path) as f:
    doc = json.load(f)

if not isinstance(doc, dict):
    sys.exit(f"check_bench: {path} is not a JSON object")

failures = []
checked = 0
for section, rows in sorted(doc.items()):
    if section.endswith("_smoke") or section.endswith("_quick"):
        continue
    if not isinstance(rows, list):
        continue
    # Rows are tagged "<shape> composed" / "<shape> fused" (see
    # SpectralBenchReport::json_rows). Compare every fused row against
    # the composed baseline of the same shape within the section.
    composed = {}
    for row in rows:
        case = row.get("case", "")
        if case.endswith(" composed"):
            composed[case[: -len(" composed")]] = row
    for row in rows:
        case = row.get("case", "")
        if not case.endswith(" fused"):
            continue
        shape = case[: -len(" fused")]
        base = composed.get(shape)
        if base is None:
            continue
        checked += 1
        fused_s, comp_s = row["mean_s"], base["mean_s"]
        tag = f"{section}: {shape} (threads={row.get('threads')})"
        if fused_s > comp_s:
            failures.append(
                f"{tag}: fused {fused_s:.6f}s > composed {comp_s:.6f}s"
            )
        else:
            print(f"check_bench: OK {tag}: fused {fused_s:.6f}s <= composed {comp_s:.6f}s")

if failures:
    print("check_bench: FUSED PATH SLOWER THAN COMPOSED BASELINE:", file=sys.stderr)
    for f_ in failures:
        print(f"  {f_}", file=sys.stderr)
    sys.exit(1)
if checked == 0:
    print("check_bench: no recorded (non-smoke, non-quick) composed/fused pairs yet; OK")
else:
    print(f"check_bench: {checked} recorded fused rows beat their composed baselines")
EOF
