#!/usr/bin/env bash
# Tier-1 CI for the Rust workspace: release build + full test suite, then
# a deterministic single-threaded re-run of the parallel parity suite.
#
# PALLAS_THREADS=1 pins the parallel executor to one worker (see
# rust/src/parallel/mod.rs), so a parity failure reported by the normal
# run can be re-checked without scheduling in play: if it persists at one
# thread the kernel itself is wrong; if it disappears the parallel
# partitioning is at fault. Data generation is thread-count invariant by
# construction (per-sample PRNG streams), which the suite also asserts.
set -euo pipefail
cd "$(dirname "$0")/.."

cargo build --release
cargo test -q

echo "== deterministic single-threaded parity re-run (PALLAS_THREADS=1) =="
PALLAS_THREADS=1 cargo test -q --test parallel_parity
PALLAS_THREADS=1 cargo test -q --test spectral_parity
PALLAS_THREADS=1 cargo test -q --test half_spectral_parity
PALLAS_THREADS=1 cargo test -q --test native_grad
PALLAS_THREADS=1 cargo test -q --test serve_parity
PALLAS_THREADS=1 cargo test -q --test lane_parity
PALLAS_THREADS=1 cargo test -q --test http_transport
PALLAS_THREADS=1 cargo test -q --test dist_parity

# Same suites pinned to eight workers: with batch sizes below the worker
# count the engines switch to within-sample row/column fan-out, so this
# leg exercises the oversubscribed partitioning that PALLAS_THREADS=1
# (and small default runners) never reach.
echo "== oversubscribed parity re-run (PALLAS_THREADS=8) =="
PALLAS_THREADS=8 cargo test -q --test parallel_parity
PALLAS_THREADS=8 cargo test -q --test spectral_parity
PALLAS_THREADS=8 cargo test -q --test half_spectral_parity
PALLAS_THREADS=8 cargo test -q --test native_grad
PALLAS_THREADS=8 cargo test -q --test serve_parity
PALLAS_THREADS=8 cargo test -q --test lane_parity
PALLAS_THREADS=8 cargo test -q --test http_transport
PALLAS_THREADS=8 cargo test -q --test dist_parity

# End-to-end native training smoke: two full epochs through the fused
# spectral engine (forward + hand-derived backward + Adam + loss scaler)
# on a tiny generated Darcy set; --expect-improve makes the binary exit
# nonzero unless the final epoch's train loss beats the first's. The
# third run uses a non-power-of-two grid so the half-spectrum rfft path
# trains through the Bluestein kernels too.
echo "== native training smoke (mpno train --native, 2 epochs) =="
cargo run --release -- train --native --dataset darcy --res 16 --n 12 \
  --batch-size 2 --width 6 --modes 3 --layers 2 --epochs 2 --lr 5e-3 \
  --seed 1 --expect-improve
cargo run --release -- train --native --dataset darcy --res 16 --n 12 \
  --batch-size 2 --width 6 --modes 3 --layers 2 --epochs 2 --lr 5e-3 \
  --seed 1 --precision bf16 --expect-improve
cargo run --release -- train --native --dataset darcy --res 20 --n 12 \
  --batch-size 2 --width 6 --modes 3 --layers 2 --epochs 2 --lr 5e-3 \
  --seed 1 --expect-improve

# Serving smoke: train a tiny native model into a real checkpoint, then
# run `mpno serve --bench` over it — the self-check mode that asserts
# the batched replies are bitwise identical to one-at-a-time serving and
# that the 2x zero-shot super-resolution probe stays finite. Re-run
# pinned to one worker (and at bf16) so the serial dispatch shape and a
# low-precision variant both execute end to end from the CLI.
echo "== serving smoke (mpno serve --bench over a trained checkpoint) =="
SERVE_CK="$(mktemp -t mpno_serve_ck.XXXXXX)"
cargo run --release -- train --native --dataset darcy --res 16 --n 12 \
  --batch-size 2 --width 6 --modes 3 --layers 2 --epochs 2 --lr 5e-3 \
  --seed 1 --checkpoint "$SERVE_CK"
cargo run --release -- serve --checkpoint "$SERVE_CK" --bench --n 8 \
  --max-batch 4
PALLAS_THREADS=1 cargo run --release -- serve --checkpoint "$SERVE_CK" \
  --bench --n 8 --max-batch 4 --precision bf16

# Network serving smoke: the same checkpoint behind `mpno serve
# --listen` on an ephemeral loopback port (--port-file publishes the
# bound port), probed end to end by the built-in `mpno infer` client —
# which asserts finite outputs and bit-identical replies for repeated
# identical requests — then drained via POST /shutdown. Both executor
# legs, so the transport runs over serial and oversubscribed dispatch.
echo "== HTTP serving smoke (mpno serve --listen / mpno infer loopback) =="
MPNO_BIN=./target/release/mpno
for T in 1 8; do
  PORT_FILE="$(mktemp -t mpno_http_port.XXXXXX)"
  PALLAS_THREADS=$T "$MPNO_BIN" serve --checkpoint "$SERVE_CK" \
    --listen 127.0.0.1:0 --port-file "$PORT_FILE" --max-batch 4 &
  SERVE_PID=$!
  trap 'kill "$SERVE_PID" 2>/dev/null || true' EXIT
  PORT=""
  for _ in $(seq 1 100); do
    PORT="$(cat "$PORT_FILE" 2>/dev/null || true)"
    [ -n "$PORT" ] && break
    sleep 0.1
  done
  if [ -z "$PORT" ]; then
    echo "serve --listen never published its port" >&2
    exit 1
  fi
  PALLAS_THREADS=$T "$MPNO_BIN" infer --url "http://127.0.0.1:$PORT" \
    --probe --n 4
  PALLAS_THREADS=$T "$MPNO_BIN" infer --url "http://127.0.0.1:$PORT" \
    --probe --n 2 --precision bf16 --encoding hex
  PALLAS_THREADS=$T "$MPNO_BIN" infer --url "http://127.0.0.1:$PORT" \
    --stats --shutdown
  wait "$SERVE_PID"
  trap - EXIT
  rm -f "$PORT_FILE"
done
rm -f "$SERVE_CK"

# Distributed training smoke: the same tiny Darcy run through the
# multi-process data-parallel runtime at world sizes 1 and 2 — each run
# is a coordinator plus spawned dist-worker processes over loopback TCP
# (--coordinator 127.0.0.1:0 binds an ephemeral port). The final
# checkpoint blob must be byte-identical across world sizes: that is
# the dist runtime's house invariant (docs/ARCHITECTURE.md), checked
# here end to end from the CLI with plain cmp. Both executor legs, so
# sharded training runs over serial and oversubscribed dispatch.
echo "== distributed training smoke (world 2 == world 1, bitwise) =="
for T in 1 8; do
  DIST_W1="$(mktemp -t mpno_dist_w1.XXXXXX)"
  DIST_W2="$(mktemp -t mpno_dist_w2.XXXXXX)"
  PALLAS_THREADS=$T "$MPNO_BIN" train --native --dataset darcy --res 16 \
    --n 12 --batch-size 2 --width 6 --modes 3 --layers 2 --epochs 2 \
    --lr 5e-3 --seed 1 --coordinator 127.0.0.1:0 --workers 1 \
    --checkpoint "$DIST_W1"
  PALLAS_THREADS=$T "$MPNO_BIN" train --native --dataset darcy --res 16 \
    --n 12 --batch-size 2 --width 6 --modes 3 --layers 2 --epochs 2 \
    --lr 5e-3 --seed 1 --coordinator 127.0.0.1:0 --workers 2 \
    --checkpoint "$DIST_W2"
  cmp "$DIST_W1" "$DIST_W2"
  rm -f "$DIST_W1" "$DIST_W2"
done

# Bench smoke: MPNO_BENCH_SMOKE=1 collapses bench_auto to 1 warmup +
# 1 iteration per case (see rust/src/bench/mod.rs), so every bench and
# experiment driver is compiled AND executed on each CI pass without
# measurement-grade runtimes. bench_runtime prints its no-pjrt notice
# and exits 0 in the default build.
echo "== bench smoke (MPNO_BENCH_SMOKE=1: 1 warmup / 1 iter per case) =="
cargo build --release --benches
MPNO_BENCH_SMOKE=1 cargo bench --bench bench_fft
MPNO_BENCH_SMOKE=1 cargo bench --bench bench_contract
MPNO_BENCH_SMOKE=1 cargo bench --bench bench_fp
MPNO_BENCH_SMOKE=1 cargo bench --bench bench_tables
MPNO_BENCH_SMOKE=1 cargo bench --bench bench_runtime
MPNO_BENCH_SMOKE=1 cargo bench --bench bench_native
MPNO_BENCH_SMOKE=1 cargo run --release -- bench-par --quick --json

# Regression gate on the recorded (non-smoke) bench rows: the fused
# path must never be slower than the composed baseline, the Hermitian
# half-spectrum path must never be slower than the full-spectrum fused
# path at the same shape and thread count, batched serving must never
# be slower than serving the same requests one at a time, the lane
# SoA contraction kernels must never be slower than their scalar
# reference at the same shape, precision and thread count, and the
# loopback HTTP transport must stay within a (lenient, overridable)
# overhead budget of in-process serving.
./scripts/check_bench.sh
