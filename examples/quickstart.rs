//! Quickstart: load an AOT artifact, run one forward pass, train a tiny
//! FNO on generated Darcy data — the 60-second tour of the public API.
//!
//! Run: `cargo run --release --example quickstart`
//! (requires `make artifacts` first.)

use mpno::coordinator::{train_grid, TrainConfig};
use mpno::data::{load_or_generate, DatasetKind, GenSpec};
use mpno::runtime::Engine;
use mpno::tensor::Tensor;

fn main() -> anyhow::Result<()> {
    let root = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    let mut engine = Engine::new(&root.join("artifacts"))?;
    println!("PJRT platform: {}", engine.platform());

    // 1. One forward pass through the full-precision FNO.
    let exe = engine.load("fno_darcy_r32_full_none_fwd")?;
    let params = engine.init_params(&exe.entry, 42);
    let x = Tensor::from_fn(&[4, 1, 32, 32], |i| {
        ((i[2] as f32 / 8.0).sin() + (i[3] as f32 / 8.0).cos()) * 0.5
    });
    let mut inputs: Vec<&Tensor> = params.iter().collect();
    inputs.push(&x);
    let out = exe.run(&inputs)?;
    println!(
        "forward OK: output {:?}, |out|max = {:.4}",
        out[0].shape(),
        out[0].abs_max()
    );

    // 2. Generate a small Darcy dataset with the built-in FD solver.
    let spec = GenSpec {
        kind: DatasetKind::DarcyFlow,
        n_samples: 24,
        resolution: 32,
        seed: 7,
    };
    let data = load_or_generate(&spec, &root.join("datasets"))?;
    let (train, test) = data.split(8);
    println!("dataset: {} train / {} test samples", train.len(), test.len());

    // 3. Train the paper's mixed-precision FNO for a few epochs.
    let mut cfg = TrainConfig::new("fno_darcy_r32_mixed_tanh_grads");
    cfg.epochs = 4;
    cfg.lr = 2e-3;
    cfg.loss_scaling = true; // AMP GradScaler
    let report = train_grid(&mut engine, &train, &test, &cfg)?;
    for e in &report.epochs {
        println!(
            "epoch {}: train {:.4}  test L2 {:.4}  H1 {:.4}  ({:.1} samples/s)",
            e.epoch, e.train_loss, e.test_l2, e.test_h1, e.samples_per_sec
        );
    }
    assert!(!report.diverged, "tanh-stabilized mixed precision must be stable");
    println!("quickstart done.");
    Ok(())
}
