//! Zero-shot super-resolution (Table 1 / discretization convergence):
//! train an FNO at 32², then evaluate the *same weights* at 64² and 128²
//! by loading the finer-grid fwd artifacts — no retraining, exploiting the
//! resolution invariance of the spectral parameterization. High-resolution
//! ground truth comes from spectrally downsampling a 128² NS dataset.
//!
//! Run: `cargo run --release --example super_resolution`

use mpno::coordinator::{evaluate_super_resolution, train_grid, TrainConfig};
use mpno::data::{load_or_generate, DatasetKind, GenSpec, GridDataset};
use mpno::runtime::Engine;
use mpno::tensor::{resample::resample_batch, Tensor};

fn main() -> anyhow::Result<()> {
    let root = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    let mut engine = Engine::new(&root.join("artifacts"))?;

    let n = 24;
    println!("generating 128x128 Navier-Stokes ground truth (this is the slow bit)...");
    let spec = GenSpec {
        kind: DatasetKind::NavierStokes,
        n_samples: n,
        resolution: 128,
        seed: 21,
    };
    let hires = load_or_generate(&spec, &root.join("datasets"))?;

    let down = |t: &Tensor, r: usize| -> Tensor {
        let b = t.shape()[0];
        let flat = t.reshape(&[b, t.shape()[2], t.shape()[3]]);
        resample_batch(&flat, r, r).reshape(&[b, 1, r, r])
    };
    let make = |r: usize| GridDataset {
        kind: DatasetKind::NavierStokes,
        inputs: down(&hires.inputs, r),
        targets: down(&hires.targets, r),
    };

    // Train at 32².
    let (train, test32) = make(32).split(n / 3);
    let mut cfg = TrainConfig::new("fno_ns_r32_mixed_tanh_grads");
    cfg.epochs = 8;
    cfg.lr = 2e-3;
    cfg.loss_scaling = true;
    println!("training mixed-precision FNO at 32x32...");
    let report = train_grid(&mut engine, &train, &test32, &cfg)?;
    println!(
        "trained: test L2 {:.4} at 32x32 (diverged: {})",
        report.final_test_l2(),
        report.diverged
    );

    // Evaluate the SAME parameters at finer resolutions.
    for r in [32usize, 64, 128] {
        let (_, test_r) = make(r).split(n / 3);
        let artifact = format!("fno_ns_r{r}_full_none_fwd");
        let (l2, h1) =
            evaluate_super_resolution(&mut engine, &report.params, &artifact, &test_r)?;
        println!("zero-shot at {r:>3}x{r:<3}: L2 {l2:.4}  H1 {h1:.4}");
    }
    println!("(discretization convergence: error stays flat under mesh refinement)");
    Ok(())
}
