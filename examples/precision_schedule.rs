//! The paper's §4.4 precision schedule: 25% mixed -> 50% AMP -> 25% full,
//! hot-swapping PJRT executables while the fp32 master weights carry over.
//! Compares final error against constant-precision training.
//!
//! Run: `cargo run --release --example precision_schedule`

use mpno::coordinator::{train_grid, PrecisionSchedule, TrainConfig};
use mpno::data::{load_or_generate, DatasetKind, GenSpec};
use mpno::runtime::Engine;

fn main() -> anyhow::Result<()> {
    let root = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    let mut engine = Engine::new(&root.join("artifacts"))?;
    let spec = GenSpec {
        kind: DatasetKind::NavierStokes,
        n_samples: 36,
        resolution: 32,
        seed: 7,
    };
    println!("generating/loading Navier-Stokes dataset (pseudo-spectral solver)...");
    let data = load_or_generate(&spec, &root.join("datasets"))?;
    let (train, test) = data.split(12);

    let schedules = [
        ("constant full", PrecisionSchedule::constant("fno_ns_r32_full_none_grads")),
        ("constant mixed", PrecisionSchedule::constant("fno_ns_r32_mixed_tanh_grads")),
        (
            "paper schedule (25% mixed / 50% amp / 25% full)",
            PrecisionSchedule::paper_default(
                "fno_ns_r32_mixed_tanh_grads",
                "fno_ns_r32_amp_none_grads",
                "fno_ns_r32_full_none_grads",
            ),
        ),
    ];

    for (label, schedule) in schedules {
        let mut cfg = TrainConfig::new("fno_ns_r32_full_none_grads");
        cfg.schedule = schedule;
        cfg.epochs = 8;
        cfg.lr = 2e-3;
        cfg.loss_scaling = true;
        let report = train_grid(&mut engine, &train, &test, &cfg)?;
        println!("\n=== {label} ===");
        for e in &report.epochs {
            println!(
                "epoch {} [{}]: train {:.4} test H1 {:.4}",
                e.epoch,
                e.artifact.split("_grads").next().unwrap(),
                e.train_loss,
                e.test_h1
            );
        }
        println!(
            "final: L2 {:.4} H1 {:.4}",
            report.final_test_l2(),
            report.final_test_h1()
        );
    }
    Ok(())
}
