//! Numerical-stability study (§4.3 / App. B.5-B.6 in miniature): drives
//! naive mixed-precision FNO into overflow with un-normalized inputs, then
//! shows (a) the global stabilizers' loss-scale collapse and (b) the tanh
//! pre-activation rescue. Prints the GradScaler telemetry that Fig. 10
//! plots.
//!
//! Run: `cargo run --release --example stability_study`

use mpno::coordinator::{train_grid, TrainConfig};
use mpno::data::{load_or_generate, DatasetKind, GenSpec, GridDataset};
use mpno::runtime::Engine;

fn main() -> anyhow::Result<()> {
    let root = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    let mut engine = Engine::new(&root.join("artifacts"))?;
    let spec = GenSpec {
        kind: DatasetKind::NavierStokes,
        n_samples: 24,
        resolution: 32,
        seed: 7,
    };
    let data = load_or_generate(&spec, &root.join("datasets"))?;
    let (train, test) = data.split(8);

    // Hostile, un-normalized inputs (raw physical scales): the f16 FFT's
    // DC bin accumulates the whole grid and overflows 65504.
    let hostile = GridDataset {
        kind: train.kind,
        inputs: train.inputs.scale(3e5),
        targets: train.targets.clone(),
    };

    println!("--- naive mixed precision (no stabilizer), dynamic loss scaling ---");
    let mut cfg = TrainConfig::new("fno_ns_r32_mixed_none_grads");
    cfg.epochs = 2;
    cfg.loss_scaling = true;
    let naive = train_grid(&mut engine, &hostile, &test, &cfg)?;
    println!(
        "diverged: {} (at step {:?}); skipped steps epoch 0: {}",
        naive.diverged,
        naive.diverged_at_step,
        naive.epochs.first().map(|e| e.skipped_steps).unwrap_or(0)
    );
    println!("loss-scale trajectory (collapsing = Fig. 10):");
    for (step, scale) in naive.scaler_history.iter().take(12) {
        println!("  step {step:>3}: scale {scale:.3e}");
    }

    println!("\n--- tanh pre-activation (the paper's fix), same data ---");
    let mut cfg = TrainConfig::new("fno_ns_r32_mixed_tanh_grads");
    cfg.epochs = 2;
    cfg.loss_scaling = true;
    let fixed = train_grid(&mut engine, &hostile, &test, &cfg)?;
    println!(
        "diverged: {}; final train loss {:.4}; final scale {:.3e}",
        fixed.diverged,
        fixed.epochs.last().map(|e| e.train_loss).unwrap_or(f64::NAN),
        fixed.scaler_history.last().map(|s| s.1).unwrap_or(f64::NAN),
    );
    assert!(!fixed.diverged);
    println!("\ntanh keeps every FFT input in [-1, 1]; overflow is impossible.");
    Ok(())
}
