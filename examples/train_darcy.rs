//! End-to-end training driver (the DESIGN.md §End-to-end validation run):
//! generates a real Darcy-flow dataset with the built-in finite-volume
//! solver, then trains full-precision and mixed-precision FNOs for a few
//! hundred steps each, logging loss curves to results/train_darcy_*.csv
//! and reporting the error gap + throughput ratio the paper claims.
//!
//! Run: `cargo run --release --example train_darcy [-- epochs]`

use mpno::coordinator::{train_grid, TrainConfig};
use mpno::data::{load_or_generate, DatasetKind, GenSpec};
use mpno::runtime::Engine;

fn main() -> anyhow::Result<()> {
    let epochs: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(12);
    let root = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    let mut engine = Engine::new(&root.join("artifacts"))?;

    // Real small workload: 48 Darcy samples at 32^2 from the FD+CG solver.
    let spec = GenSpec {
        kind: DatasetKind::DarcyFlow,
        n_samples: 48,
        resolution: 32,
        seed: 7,
    };
    println!("generating/loading Darcy dataset (FD + CG solver)...");
    let data = load_or_generate(&spec, &root.join("datasets"))?;
    let (train, test) = data.split(16);

    let mut results = vec![];
    for (label, artifact, scaling) in [
        ("full-precision", "fno_darcy_r32_full_none_grads", false),
        ("mixed-precision (ours)", "fno_darcy_r32_mixed_tanh_grads", true),
    ] {
        println!("\n=== {label} ===");
        let mut cfg = TrainConfig::new(artifact);
        cfg.epochs = epochs;
        cfg.lr = 2e-3;
        cfg.loss_scaling = scaling;
        cfg.log_path = Some(root.join(format!(
            "results/train_darcy_{}.csv",
            label.split_whitespace().next().unwrap()
        )));
        let report = train_grid(&mut engine, &train, &test, &cfg)?;
        for e in &report.epochs {
            println!(
                "epoch {:>3}: train H1 {:.4}  test L2 {:.4}  test H1 {:.4}  {:.2}s",
                e.epoch, e.train_loss, e.test_l2, e.test_h1, e.seconds
            );
        }
        println!(
            "{label}: final test L2 {:.4}, H1 {:.4}, {:.1} samples/s",
            report.final_test_l2(),
            report.final_test_h1(),
            report.mean_throughput()
        );
        results.push((label, report));
    }

    let (full, mixed) = (&results[0].1, &results[1].1);
    let gap = (mixed.final_test_h1() - full.final_test_h1()).abs()
        / full.final_test_h1().max(1e-12);
    println!(
        "\nsummary: H1 gap mixed-vs-full = {:.2}% (paper: < 1% at convergence); \
         throughput ratio = {:.2}x (CPU; paper GPU: 1.23-1.58x)",
        100.0 * gap,
        mixed.mean_throughput() / full.mean_throughput()
    );
    Ok(())
}
