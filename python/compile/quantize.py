"""Emulated low-precision casts usable inside jitted/AOT-lowered graphs.

The paper's method runs the FNO block in half precision on CUDA; our PJRT
target is CPU, so each reduced-precision format is emulated by a
round-trip cast that reproduces the format's *rounding and range* exactly
(bit-checked against the Rust softfloat in ``rust/src/fp`` — see
python/tests/test_quantize.py which loads vectors dumped by
``mpno dump-fp-vectors``).

Backward rounding: JAX's grad of ``convert_element_type`` is another
convert (i.e. the cotangent is NOT rounded). We wrap every cast in a
``custom_vjp`` that also rounds the cotangent, modelling a backward pass
executed in the same precision — this is what makes the Fig. 10 loss-scale
collapse and Fig. 16 FP8 divergence reproducible.
"""

import jax
import jax.numpy as jnp

FULL = "full"
AMP = "amp"
MIXED = "mixed"
BF16 = "bf16"
FP8 = "fp8"
TF32 = "tf32"

ALL_MODES = (FULL, AMP, MIXED, BF16, FP8, TF32)

# Max finite magnitudes.
F16_MAX = 65504.0
E5M2_MAX = 57344.0


def _round_f16(x):
    return x.astype(jnp.float16).astype(jnp.float32)


def _round_bf16(x):
    return x.astype(jnp.bfloat16).astype(jnp.float32)


def _round_tf32(x):
    """Truncate the f32 mantissa to 10 bits with round-to-nearest-even.

    Implemented with integer bit twiddling (bitcast -> add rounding bias ->
    mask), identical to ``rust/src/fp/tf32.rs``.
    """
    bits = jax.lax.bitcast_convert_type(x, jnp.uint32)
    # RNE: add 0xFFF + lsb-of-kept, then clear the 13 dropped bits.
    lsb = (bits >> jnp.uint32(13)) & jnp.uint32(1)
    bias = jnp.uint32(0xFFF) + lsb
    rounded = (bits + bias) & jnp.uint32(0xFFFFE000)
    out = jax.lax.bitcast_convert_type(rounded, jnp.float32)
    # Preserve NaN/Inf unchanged.
    return jnp.where(jnp.isfinite(x), out, x)


def _round_fp8(x):
    """E5M2 emulation: round to fp16 first, then RNE-truncate the mantissa
    to 2 bits by integer bit-twiddling on the f16 encoding, then clip to the
    E5M2 range. (The paper's own simulation only range-clips; we keep the
    mantissa truncation too so FP8's missing precision bits — the mechanism
    Theorem 3.2 blames for its divergence — are actually modelled. Twin
    implementation: rust/src/fp/mod.rs::round_trip.)"""
    h = x.astype(jnp.float16)
    bits = jax.lax.bitcast_convert_type(h, jnp.uint16)
    lsb = (bits >> jnp.uint16(8)) & jnp.uint16(1)
    rounded = (bits + jnp.uint16(0x7F) + lsb) & jnp.uint16(0xFF00)
    h2 = jax.lax.bitcast_convert_type(rounded, jnp.float16).astype(jnp.float32)
    out = jnp.clip(h2, -E5M2_MAX, E5M2_MAX)
    return jnp.where(jnp.isfinite(x), out, x)


_SPECTRAL_ROUNDERS = {
    FULL: lambda x: x,
    AMP: lambda x: x,  # stock AMP leaves complex/spectral ops in f32
    MIXED: _round_f16,
    BF16: _round_bf16,
    FP8: _round_fp8,
    TF32: _round_tf32,
}

_DENSE_ROUNDERS = {
    FULL: lambda x: x,
    AMP: _round_f16,  # AMP autocasts real matmul-like ops
    MIXED: _round_f16,
    BF16: _round_bf16,
    FP8: _round_f16,  # paper simulates FP8 only in the FNO block
    TF32: _round_tf32,
}


def _make_cast(rounder):
    @jax.custom_vjp
    def cast(x):
        return rounder(x)

    def fwd(x):
        return rounder(x), None

    def bwd(_, g):
        return (rounder(g),)

    cast.defvjp(fwd, bwd)
    return cast


_SPECTRAL_CASTS = {m: _make_cast(r) for m, r in _SPECTRAL_ROUNDERS.items()}
_DENSE_CASTS = {m: _make_cast(r) for m, r in _DENSE_ROUNDERS.items()}


def spectral_cast(x, mode):
    """Rounding applied to FNO-block (spectral-domain) values under `mode`.

    Complex inputs are rounded per component (torch.chalf semantics).
    """
    cast = _SPECTRAL_CASTS[mode]
    if jnp.iscomplexobj(x):
        return cast(jnp.real(x)) + 1j * cast(jnp.imag(x))
    return cast(x)


def dense_cast(x, mode):
    """Rounding applied to real-valued (non-FNO-block) ops under `mode`."""
    return _DENSE_CASTS[mode](x)


def spectral_bytes(mode):
    """Bytes per complex spectral activation element (memory model twin of
    ``Precision::spectral_activation_bytes``)."""
    return {FULL: 8, AMP: 8, TF32: 8, MIXED: 4, BF16: 4, FP8: 2}[mode]


def dense_bytes(mode):
    return {FULL: 4, TF32: 4, AMP: 2, MIXED: 2, BF16: 2, FP8: 1}[mode]
