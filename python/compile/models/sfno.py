"""L2 — SFNO-lite: Spherical Fourier Neural Operator (Bonev et al. 2023)
for the shallow-water dataset.

The spherical harmonic transform (SHT) is implemented as precomputed
matrices: an FFT in longitude followed by per-order associated-Legendre
quadrature in latitude,

    a_lm = sum_i w_i  P̄_l^m(cos θ_i)  f̂_m(θ_i),

with P̄ the orthonormalized associated Legendre functions (same recurrence
as ``rust/src/linalg``) and w_i = sin θ_i Δθ quadrature weights on the
equiangular dataset grid (approximate orthogonality — documented
substitution for torch-harmonics' Gauss-Legendre grid; exact enough for
lmax <= nlat/2, checked in pytest).

The SFNO block weight depends on degree l only (a zonally-equivariant
kernel, as in the paper); the contraction is routed through the same L1
Pallas kernel as FNO by broadcasting the weight over m — so SFNO exercises
the identical mixed-precision hot path.
"""

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from compile import quantize as q
from compile.kernels import spectral_conv as sc


@dataclasses.dataclass(frozen=True)
class SfnoConfig:
    in_channels: int = 3
    out_channels: int = 3
    width: int = 24
    lmax: int = 10
    layers: int = 4
    nlat: int = 16
    nlon: int = 32
    mode: str = q.FULL
    stabilizer: str = "none"


def _assoc_legendre_normalized(lmax, m, x):
    """Orthonormalized P̄_l^m(x), l = m..lmax (numpy twin of rust linalg)."""
    out = np.zeros(lmax - m + 1)
    pmm = np.sqrt(1.0 / (4.0 * np.pi))
    if m > 0:
        sx2 = max((1.0 - x) * (1.0 + x), 0.0)
        for k in range(1, m + 1):
            pmm *= -np.sqrt((2 * k + 1) / (2.0 * k)) * np.sqrt(sx2)
    out[0] = pmm
    if lmax == m:
        return out
    pmm1 = x * np.sqrt(2 * m + 3) * pmm
    out[1] = pmm1
    plm2, plm1 = pmm, pmm1
    for l in range(m + 2, lmax + 1):
        a = np.sqrt((4.0 * l * l - 1.0) / (l * l - m * m))
        b = np.sqrt(((l - 1.0) ** 2 - m * m) / (4.0 * (l - 1.0) ** 2 - 1.0))
        pl = a * (x * plm1 - b * plm2)
        out[l - m] = pl
        plm2, plm1 = plm1, pl
    return out


@functools.lru_cache(maxsize=8)
def sht_matrices(nlat, lmax):
    """(analysis, synthesis) Legendre tables.

    analysis[m]  : (lmax+1, nlat)  — includes quadrature weights
    synthesis[m] : (nlat, lmax+1)  — pure P̄ values
    Entries with l < m are zero.
    """
    theta = np.pi * (np.arange(nlat) + 0.5) / nlat
    ct = np.cos(theta)
    wq = np.sin(theta) * (np.pi / nlat) * 2.0 * np.pi  # includes the phi
    ana = np.zeros((lmax + 1, lmax + 1, nlat))
    syn = np.zeros((lmax + 1, nlat, lmax + 1))
    for m in range(lmax + 1):
        for i in range(nlat):
            p = _assoc_legendre_normalized(lmax, m, ct[i])
            for l in range(m, lmax + 1):
                ana[m, l, i] = p[l - m] * wq[i]
                syn[m, i, l] = p[l - m]
    # Return *numpy* arrays: numpy constants are inlined into the lowered
    # HLO as literals, whereas jnp DeviceArrays captured by closure are
    # hoisted to runtime parameters — which would silently change the
    # artifact's input arity (the Rust engine feeds manifest inputs only).
    return ana.astype(np.float32), syn.astype(np.float32)


def sht(v, lmax):
    """Forward SHT: v (b, c, nlat, nlon) real -> a (b, c, lmax+1, lmax+1)
    complex coefficients indexed (l, m), m >= 0 (real-field symmetry)."""
    nlat, nlon = v.shape[-2], v.shape[-1]
    ana, _ = sht_matrices(nlat, lmax)
    fm = jnp.fft.fft(v.astype(jnp.complex64), axis=-1) / nlon  # (b,c,lat,m)
    fm = fm[..., : lmax + 1]  # keep m = 0..lmax
    # a[b,c,l,m] = sum_i ana[m,l,i] fm[b,c,i,m]
    return jnp.einsum("mli,bcim->bclm", ana.astype(jnp.complex64), fm)


def isht(a, nlat, nlon):
    """Inverse SHT back to the (nlat, nlon) grid (real part)."""
    lmax = a.shape[-2] - 1
    _, syn = sht_matrices(nlat, lmax)
    # f̂_m(θ_i) = sum_l syn[m,i,l] a[l,m]
    fm = jnp.einsum("mil,bclm->bcim", jnp.asarray(syn, jnp.complex64), a)
    # Assemble the full FFT line with Hermitian symmetry for m>0.
    full = jnp.zeros(a.shape[:2] + (nlat, nlon), jnp.complex64)
    full = full.at[..., 0].set(fm[..., 0])
    for m in range(1, lmax + 1):
        full = full.at[..., m].set(fm[..., m])
        full = full.at[..., nlon - m].set(jnp.conj(fm[..., m]))
    return jnp.real(jnp.fft.ifft(full, axis=-1)) * nlon


def param_specs(cfg: SfnoConfig):
    w = cfg.width
    L = cfg.lmax + 1
    cin = cfg.in_channels + 2
    specs = [("lift_w", (cin, w), (1.0 / cin) ** 0.5), ("lift_b", (w,), 0.0)]
    for l in range(cfg.layers):
        specs.append((f"blk{l}_wspec", (w, w, L, 2), (1.0 / (w * w)) ** 0.5))
        specs.append((f"blk{l}_skip_w", (w, w), (1.0 / w) ** 0.5))
        specs.append((f"blk{l}_skip_b", (w,), 0.0))
    specs += [
        ("proj1_w", (w, 2 * w), (1.0 / w) ** 0.5),
        ("proj1_b", (2 * w,), 0.0),
        ("proj2_w", (2 * w, cfg.out_channels), (1.0 / (2 * w)) ** 0.5),
        ("proj2_b", (cfg.out_channels,), 0.0),
    ]
    return specs


def init_params(rng, cfg: SfnoConfig):
    params = {}
    for name, shape, std in param_specs(cfg):
        rng, sub = jax.random.split(rng)
        params[name] = (
            jnp.zeros(shape, jnp.float32)
            if std == 0.0
            else std * jax.random.normal(sub, shape, jnp.float32)
        )
    return params


def _stabilize(v, kind):
    if kind == "tanh":
        return jnp.tanh(v)
    if kind == "none":
        return v
    raise ValueError(kind)


def spherical_block(params, prefix, v, cfg: SfnoConfig):
    mode = cfg.mode
    L = cfg.lmax + 1
    v = _stabilize(v, cfg.stabilizer)
    v = q.spectral_cast(v, mode)
    a = sht(v, cfg.lmax)  # (b, c, L, M)
    a = q.spectral_cast(a, mode)
    # Weight w[i,o,l] broadcast over m -> reuse the 2-D Pallas kernel.
    wspec = params[f"{prefix}_wspec"]  # (i, o, L, 2)
    wr = jnp.broadcast_to(wspec[..., 0][:, :, :, None], wspec.shape[:2] + (L, L))
    wi = jnp.broadcast_to(wspec[..., 1][:, :, :, None], wspec.shape[:2] + (L, L))
    out_r, out_i = sc.spectral_contract(jnp.real(a), jnp.imag(a), wr, wi, mode)
    a2 = out_r + 1j * out_i
    a2 = q.spectral_cast(a2, mode)
    out = isht(a2, cfg.nlat, cfg.nlon)
    return q.spectral_cast(out, mode)


def _conv1x1(v, wmat, b, mode):
    v = q.dense_cast(v, mode)
    wmat = q.dense_cast(wmat, mode)
    out = jnp.einsum("bchw,cd->bdhw", v, wmat) + b[None, :, None, None]
    return q.dense_cast(out, mode)


def forward(params, x, cfg: SfnoConfig):
    b, _, nlat, nlon = x.shape
    # Coordinate channels: cos(theta), sin(theta) (zonal symmetry).
    theta = jnp.pi * (jnp.arange(nlat) + 0.5) / nlat
    ct = jnp.broadcast_to(jnp.cos(theta)[None, None, :, None], (b, 1, nlat, nlon))
    st = jnp.broadcast_to(jnp.sin(theta)[None, None, :, None], (b, 1, nlat, nlon))
    v = jnp.concatenate([x, ct, st], axis=1)
    v = _conv1x1(v, params["lift_w"], params["lift_b"], cfg.mode)
    for l in range(cfg.layers):
        spec = spherical_block(params, f"blk{l}", v, cfg)
        skip = _conv1x1(v, params[f"blk{l}_skip_w"], params[f"blk{l}_skip_b"], cfg.mode)
        v = jax.nn.gelu(spec + skip)
    v = _conv1x1(v, params["proj1_w"], params["proj1_b"], cfg.mode)
    v = jax.nn.gelu(v)
    return _conv1x1(v, params["proj2_w"], params["proj2_b"], cfg.mode)
