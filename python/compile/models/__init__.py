"""L2 model definitions (FNO/TFNO, SFNO-lite, GINO-lite, U-Net)."""
