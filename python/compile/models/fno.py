"""L2 — FNO / TFNO model definition (the paper's main architecture).

Pure-functional JAX: ``init_params`` returns an ordered dict of real f32
arrays (complex spectral weights are stored as trailing-dim re/im pairs so
the HLO interface stays all-real — see DESIGN.md), ``forward`` maps
(params, x) -> y and is what gets AOT-lowered.

Precision modes (python/compile/quantize.py) reproduce the paper's
configurations:

* ``full``  — everything f32 (baseline),
* ``amp``   — real-valued convs/MLPs rounded to f16, FNO block f32
              (what stock torch AMP does to FNO),
* ``mixed`` — AMP **plus** the FNO block in f16: the input of the forward
              FFT, the Pallas tensor contraction and the inverse FFT are
              all computed under f16 rounding (the paper's method),
* ``bf16`` / ``fp8`` / ``tf32`` — the App. B.11 alternatives.

Stabilizers (§4.3 / App. B.6) are pre-activations applied before each
forward FFT: ``none``, ``tanh`` (the paper's choice), ``hardclip``,
``sigclip`` (2sigma-clip), ``div`` (fixed division).
"""

import dataclasses

import jax
import jax.numpy as jnp

from compile import quantize as q
from compile.kernels import spectral_conv as sc


@dataclasses.dataclass(frozen=True)
class FnoConfig:
    in_channels: int = 1
    out_channels: int = 1
    width: int = 32
    modes: int = 8          # modes kept per spectral axis side
    layers: int = 4
    height: int = 32
    width_grid: int = 32    # spatial W
    mode: str = q.FULL      # precision mode
    stabilizer: str = "none"
    cp_rank: int = 0        # 0 = dense weights, >0 = CP factorization
    input_scale: float = 1.0  # stability experiments un-normalize inputs
    # Table 4 per-site overrides: precision tokens for (forward FFT,
    # contraction, inverse FFT). None -> follow `mode` everywhere.
    site_precisions: tuple = None


def param_specs(cfg: FnoConfig):
    """Ordered (name, shape, init_std) — shared with the Rust manifest."""
    w = cfg.width
    m2 = 2 * cfg.modes
    specs = []
    # Lifting (1x1 conv over channels + 2 coordinate channels).
    cin = cfg.in_channels + 2
    specs.append(("lift_w", (cin, w), (1.0 / cin) ** 0.5))
    specs.append(("lift_b", (w,), 0.0))
    for l in range(cfg.layers):
        if cfg.cp_rank > 0:
            r = cfg.cp_rank
            scale = (1.0 / (w * w)) ** 0.5
            specs.append((f"blk{l}_lam", (r,), scale))
            for nm, dim in (("fi", w), ("fo", w), ("fx", m2), ("fy", m2)):
                specs.append((f"blk{l}_{nm}", (dim, r, 2), (1.0 / dim) ** 0.5))
        else:
            specs.append(
                (f"blk{l}_wspec", (w, w, m2, m2, 2), (1.0 / (w * w)) ** 0.5)
            )
        specs.append((f"blk{l}_skip_w", (w, w), (1.0 / w) ** 0.5))
        specs.append((f"blk{l}_skip_b", (w,), 0.0))
    specs.append(("proj1_w", (w, 2 * w), (1.0 / w) ** 0.5))
    specs.append(("proj1_b", (2 * w,), 0.0))
    specs.append(("proj2_w", (2 * w, cfg.out_channels), (1.0 / (2 * w)) ** 0.5))
    specs.append(("proj2_b", (cfg.out_channels,), 0.0))
    return specs


def init_params(rng, cfg: FnoConfig):
    params = {}
    for name, shape, std in param_specs(cfg):
        rng, sub = jax.random.split(rng)
        if std == 0.0:
            params[name] = jnp.zeros(shape, jnp.float32)
        else:
            params[name] = std * jax.random.normal(sub, shape, jnp.float32)
    return params


def _stabilize(v, kind):
    if kind == "none":
        return v
    if kind == "tanh":
        return jnp.tanh(v)
    if kind == "hardclip":
        return jnp.clip(v, -1.0, 1.0)
    if kind == "sigclip":
        mu = jnp.mean(v, axis=(-2, -1), keepdims=True)
        sd = jnp.std(v, axis=(-2, -1), keepdims=True)
        return jnp.clip(v, mu - 2.0 * sd, mu + 2.0 * sd)
    if kind == "div":
        return v / 100.0
    raise ValueError(f"unknown stabilizer {kind!r}")


def _truncate_modes(vh, m):
    """Gather the four low-frequency corners into a (.., 2m, 2m) block."""
    tl = vh[:, :, :m, :m]
    tr = vh[:, :, :m, -m:]
    bl = vh[:, :, -m:, :m]
    br = vh[:, :, -m:, -m:]
    top = jnp.concatenate([tl, tr], axis=-1)
    bot = jnp.concatenate([bl, br], axis=-1)
    return jnp.concatenate([top, bot], axis=-2)


def _scatter_modes(block, h, w):
    """Inverse of _truncate_modes: place corners into an (h, w) spectrum."""
    b, c, m2, _ = block.shape
    m = m2 // 2
    out = jnp.zeros((b, c, h, w), block.dtype)
    out = out.at[:, :, :m, :m].set(block[:, :, :m, :m])
    out = out.at[:, :, :m, -m:].set(block[:, :, :m, m:])
    out = out.at[:, :, -m:, :m].set(block[:, :, m:, :m])
    out = out.at[:, :, -m:, -m:].set(block[:, :, m:, m:])
    return out


def spectral_block(params, prefix, v, cfg: FnoConfig):
    """One Fourier layer: stabilize -> FFT -> truncate -> contract (Pallas)
    -> scatter -> iFFT, all under the precision mode's rounding."""
    mode = cfg.mode
    # Per-site precisions (Table 4 ablation); default: mode everywhere.
    fft_p, con_p, ifft_p = cfg.site_precisions or (mode, mode, mode)
    h, w = v.shape[-2], v.shape[-1]
    v = _stabilize(v, cfg.stabilizer)
    # Forward FFT in reduced precision: round the input, transform, round
    # the spectrum (per-op rounding model of a half FFT).
    v = q.spectral_cast(v, fft_p)
    vh = jnp.fft.fft2(v.astype(jnp.complex64))
    vh = q.spectral_cast(vh, fft_p)
    blk = _truncate_modes(vh, cfg.modes)
    xr, xi = jnp.real(blk), jnp.imag(blk)
    if cfg.cp_rank > 0:
        out_r, out_i = sc.cp_contract(
            xr,
            xi,
            params[f"{prefix}_lam"],
            params[f"{prefix}_fi"][..., 0],
            params[f"{prefix}_fi"][..., 1],
            params[f"{prefix}_fo"][..., 0],
            params[f"{prefix}_fo"][..., 1],
            params[f"{prefix}_fx"][..., 0],
            params[f"{prefix}_fx"][..., 1],
            params[f"{prefix}_fy"][..., 0],
            params[f"{prefix}_fy"][..., 1],
            mode=con_p,
        )
    else:
        wspec = params[f"{prefix}_wspec"]
        out_r, out_i = sc.spectral_contract(
            xr, xi, wspec[..., 0], wspec[..., 1], con_p
        )
    full = _scatter_modes(out_r + 1j * out_i, h, w)
    # Inverse FFT in reduced precision.
    full = q.spectral_cast(full, ifft_p)
    out = jnp.real(jnp.fft.ifft2(full))
    return q.spectral_cast(out, ifft_p)


def _conv1x1(v, wmat, b, mode):
    v = q.dense_cast(v, mode)
    wmat = q.dense_cast(wmat, mode)
    out = jnp.einsum("bchw,cd->bdhw", v, wmat) + b[None, :, None, None]
    return q.dense_cast(out, mode)


def _coord_grid(b, h, w):
    ys = jnp.linspace(0.0, 1.0, h)
    xs = jnp.linspace(0.0, 1.0, w)
    gy, gx = jnp.meshgrid(ys, xs, indexing="ij")
    g = jnp.stack([gy, gx])[None]  # (1, 2, h, w)
    return jnp.broadcast_to(g, (b, 2, h, w))


def forward(params, x, cfg: FnoConfig):
    """FNO forward: x (b, c_in, h, w) -> (b, c_out, h, w)."""
    b, _, h, w = x.shape
    x = x * cfg.input_scale
    v = jnp.concatenate([x, _coord_grid(b, h, w)], axis=1)
    v = _conv1x1(v, params["lift_w"], params["lift_b"], cfg.mode)
    for l in range(cfg.layers):
        spec = spectral_block(params, f"blk{l}", v, cfg)
        skip = _conv1x1(v, params[f"blk{l}_skip_w"], params[f"blk{l}_skip_b"], cfg.mode)
        v = jax.nn.gelu(spec + skip)
    v = _conv1x1(v, params["proj1_w"], params["proj1_b"], cfg.mode)
    v = jax.nn.gelu(v)
    v = _conv1x1(v, params["proj2_w"], params["proj2_b"], cfg.mode)
    return v
