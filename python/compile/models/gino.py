"""L2 — GINO-lite: Geometry-Informed Neural Operator (Li et al. 2023) for
the Shape-Net Car / Ahmed-body point-cloud datasets.

Architecture (faithful to the paper's shape, CPU-scaled):

  point features --MLP--> latent --(fixed kernel to_grid matmul)--> grid
  --> 3-D FNO (Pallas contraction, mixed-precision hot path) -->
  --(from_grid matmul)--> points --concat skip--MLP--> pressure

The graph-neural-operator kernel integrals are the precomputed Gaussian
interpolation matrices produced by ``rust/src/pde/geometry.rs`` and fed as
*inputs* (they depend on each sample's point cloud; batch size is 1 for
geometry datasets, exactly as in the paper — App. B.3).
"""

import dataclasses

import jax
import jax.numpy as jnp

from compile import quantize as q
from compile.kernels import spectral_conv as sc


@dataclasses.dataclass(frozen=True)
class GinoConfig:
    n_points: int = 256
    grid: int = 8            # latent grid g (g^3 nodes)
    in_features: int = 7     # xyz + normals + inlet
    width: int = 24
    modes: int = 2           # per-axis spectral modes in the latent FNO
    layers: int = 2
    mode: str = q.FULL
    stabilizer: str = "none"


def param_specs(cfg: GinoConfig):
    w = cfg.width
    m2 = 2 * cfg.modes
    specs = [
        ("enc1_w", (cfg.in_features, w), (1.0 / cfg.in_features) ** 0.5),
        ("enc1_b", (w,), 0.0),
        ("enc2_w", (w, w), (1.0 / w) ** 0.5),
        ("enc2_b", (w,), 0.0),
    ]
    for l in range(cfg.layers):
        specs.append((f"blk{l}_wspec", (w, w, m2, m2, m2, 2), (1.0 / (w * w)) ** 0.5))
        specs.append((f"blk{l}_skip_w", (w, w), (1.0 / w) ** 0.5))
        specs.append((f"blk{l}_skip_b", (w,), 0.0))
    specs += [
        ("dec1_w", (2 * w, w), (1.0 / (2 * w)) ** 0.5),
        ("dec1_b", (w,), 0.0),
        ("dec2_w", (w, 1), (1.0 / w) ** 0.5),
        ("dec2_b", (1,), 0.0),
    ]
    return specs


def init_params(rng, cfg: GinoConfig):
    params = {}
    for name, shape, std in param_specs(cfg):
        rng, sub = jax.random.split(rng)
        params[name] = (
            jnp.zeros(shape, jnp.float32)
            if std == 0.0
            else std * jax.random.normal(sub, shape, jnp.float32)
        )
    return params


def _truncate_modes_3d(vh, m):
    """Gather the 8 low-frequency corners into (.., 2m, 2m, 2m)."""
    parts_z = []
    for zsl in (slice(0, m), slice(-m, None)):
        parts_y = []
        for ysl in (slice(0, m), slice(-m, None)):
            lo = vh[:, :, :m, ysl, zsl]
            hi = vh[:, :, -m:, ysl, zsl]
            parts_y.append(jnp.concatenate([lo, hi], axis=2))
        parts_z.append(jnp.concatenate(parts_y, axis=3))
    return jnp.concatenate(parts_z, axis=4)


def _scatter_modes_3d(block, g):
    b, c, m2, _, _ = block.shape
    m = m2 // 2
    out = jnp.zeros((b, c, g, g, g), block.dtype)
    for xi, xsl in ((0, slice(0, m)), (1, slice(-m, None))):
        for yi, ysl in ((0, slice(0, m)), (1, slice(-m, None))):
            for zi, zsl in ((0, slice(0, m)), (1, slice(-m, None))):
                src = block[
                    :,
                    :,
                    xi * m : xi * m + m,
                    yi * m : yi * m + m,
                    zi * m : zi * m + m,
                ]
                out = out.at[:, :, xsl, ysl, zsl].set(src)
    return out


def _stabilize(v, kind):
    if kind == "tanh":
        return jnp.tanh(v)
    if kind == "none":
        return v
    raise ValueError(kind)


def fno3d_block(params, prefix, v, cfg: GinoConfig):
    """v: (b, c, g, g, g)."""
    mode = cfg.mode
    g = v.shape[-1]
    v = _stabilize(v, cfg.stabilizer)
    v = q.spectral_cast(v, mode)
    vh = jnp.fft.fftn(v.astype(jnp.complex64), axes=(-3, -2, -1))
    vh = q.spectral_cast(vh, mode)
    blk = _truncate_modes_3d(vh, cfg.modes)
    wspec = params[f"{prefix}_wspec"]
    out_r, out_i = sc.spectral_contract_3d(
        jnp.real(blk), jnp.imag(blk), wspec[..., 0], wspec[..., 1], mode
    )
    full = _scatter_modes_3d(out_r + 1j * out_i, g)
    full = q.spectral_cast(full, mode)
    out = jnp.real(jnp.fft.ifftn(full, axes=(-3, -2, -1)))
    return q.spectral_cast(out, mode)


def _mlp(v, wname, params, mode):
    v = q.dense_cast(v, mode)
    w = q.dense_cast(params[wname + "_w"], mode)
    return q.dense_cast(v @ w + params[wname + "_b"], mode)


def forward(params, feats, to_grid, from_grid, cfg: GinoConfig):
    """feats (b, p, 7), to_grid (b, g^3, p), from_grid (b, p, g^3)
    -> pressure (b, p)."""
    b, p, _ = feats.shape
    g = cfg.grid
    m = cfg.mode
    # Encoder MLP per point.
    h = jax.nn.gelu(_mlp(feats, "enc1", params, m))
    h = jax.nn.gelu(_mlp(h, "enc2", params, m))
    # Kernel integral onto the latent grid (fixed weights, learned values).
    vg = q.dense_cast(jnp.einsum("bgp,bpc->bgc", q.dense_cast(to_grid, m), h), m)
    v = jnp.transpose(vg, (0, 2, 1)).reshape(b, cfg.width, g, g, g)
    for l in range(cfg.layers):
        spec = fno3d_block(params, f"blk{l}", v, cfg)
        vflat = v.reshape(b, cfg.width, -1)
        skip = jnp.einsum(
            "bcg,cd->bdg", vflat, q.dense_cast(params[f"blk{l}_skip_w"], m)
        ) + params[f"blk{l}_skip_b"][None, :, None]
        v = jax.nn.gelu(spec + skip.reshape(v.shape))
    # Back to the points.
    vflat = v.reshape(b, cfg.width, -1)
    vp = jnp.einsum("bpg,bcg->bpc", q.dense_cast(from_grid, m), vflat)
    z = jnp.concatenate([vp, h], axis=-1)
    z = jax.nn.gelu(_mlp(z, "dec1", params, m))
    out = _mlp(z, "dec2", params, m)
    return out[..., 0]
