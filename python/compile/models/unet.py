"""L2 — U-Net baseline (paper §4.5, Table 2).

A compact 2-level U-Net (conv3x3 + GELU, stride-2 down, nearest-neighbour
up, skip concatenation). Under ``amp`` every conv runs with f16 rounding —
the "U-Net + AMP" row of Table 2. There is no spectral domain, which is
exactly why AMP alone already captures most of its savings (24.9-20.9%
paper) while FNO needs the paper's method for its complex-valued block.
"""

import dataclasses

import jax
import jax.numpy as jnp

from compile import quantize as q


@dataclasses.dataclass(frozen=True)
class UnetConfig:
    in_channels: int = 1
    out_channels: int = 1
    width: int = 16
    height: int = 32
    width_grid: int = 32
    mode: str = q.FULL


def param_specs(cfg: UnetConfig):
    w = cfg.width
    c = cfg.in_channels
    specs = []

    def conv(name, cin, cout):
        specs.append((name + "_w", (3, 3, cin, cout), (2.0 / (9 * cin)) ** 0.5))
        specs.append((name + "_b", (cout,), 0.0))

    conv("enc1a", c, w)
    conv("enc1b", w, w)
    conv("enc2a", w, 2 * w)
    conv("enc2b", 2 * w, 2 * w)
    conv("mid", 2 * w, 2 * w)
    conv("dec2a", 4 * w, 2 * w)  # after skip concat
    conv("dec2b", 2 * w, w)
    conv("dec1a", 2 * w, w)
    conv("dec1b", w, w)
    specs.append(("out_w", (1, 1, w, cfg.out_channels), (1.0 / w) ** 0.5))
    specs.append(("out_b", (cfg.out_channels,), 0.0))
    return specs


def init_params(rng, cfg: UnetConfig):
    params = {}
    for name, shape, std in param_specs(cfg):
        rng, sub = jax.random.split(rng)
        params[name] = (
            jnp.zeros(shape, jnp.float32)
            if std == 0.0
            else std * jax.random.normal(sub, shape, jnp.float32)
        )
    return params


def _conv(v, wname, params, mode, stride=1):
    w = q.dense_cast(params[wname + "_w"], mode)
    v = q.dense_cast(v, mode)
    out = jax.lax.conv_general_dilated(
        v,
        w,
        window_strides=(stride, stride),
        padding="SAME",
        dimension_numbers=("NCHW", "HWIO", "NCHW"),
    )
    out = out + params[wname + "_b"][None, :, None, None]
    return q.dense_cast(out, mode)


def _up2(v):
    b, c, h, w = v.shape
    v = jnp.repeat(v, 2, axis=2)
    return jnp.repeat(v, 2, axis=3)


def forward(params, x, cfg: UnetConfig):
    m = cfg.mode
    e1 = jax.nn.gelu(_conv(x, "enc1a", params, m))
    e1 = jax.nn.gelu(_conv(e1, "enc1b", params, m))
    e2 = jax.nn.gelu(_conv(e1, "enc2a", params, m, stride=2))
    e2 = jax.nn.gelu(_conv(e2, "enc2b", params, m))
    mid = jax.nn.gelu(_conv(e2, "mid", params, m))
    d2 = jnp.concatenate([mid, e2], axis=1)
    d2 = jax.nn.gelu(_conv(d2, "dec2a", params, m))
    d2 = jax.nn.gelu(_conv(d2, "dec2b", params, m))
    d1 = jnp.concatenate([_up2(d2), e1], axis=1)
    d1 = jax.nn.gelu(_conv(d1, "dec1a", params, m))
    d1 = jax.nn.gelu(_conv(d1, "dec1b", params, m))
    return _conv(d1, "out", params, m)
