"""Build-time compile path: JAX/Pallas models AOT-lowered to HLO text.

Never imported at runtime — the Rust binary consumes artifacts/ only.
"""
