"""AOT artifact builder: lowers every (model x dataset x precision x
stabilizer x graph) the experiments need to HLO **text** + a manifest.

HLO text, not serialized protos: jax >= 0.5 emits HloModuleProto with
64-bit instruction ids which xla_extension 0.5.1 (the version the `xla`
crate binds) rejects; the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Incremental: a content hash over python/compile is stored in
artifacts/.inputs_hash — `make artifacts` is a no-op when nothing changed.

Run from python/:  python -m compile.aot [--out-dir ../artifacts] [--only NAME_SUBSTR]
"""

import argparse
import dataclasses
import hashlib
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import quantize as q
from compile import train_graph
from compile.models import fno, gino, sfno, unet

F32 = jnp.float32


@dataclasses.dataclass
class Artifact:
    name: str
    model: str
    dataset: str
    graph: str  # fwd | grads
    precision: str
    stabilizer: str
    loss: str
    batch: int
    cfg: object


# ---------------------------------------------------------------------------
# The artifact matrix (see DESIGN.md per-experiment index).
# ---------------------------------------------------------------------------

NS = dict(res=32, batch=4, cin=1, cout=1, loss="h1")
DARCY = dict(res=32, batch=4, cin=1, cout=1, loss="h1")
SWE = dict(nlat=16, nlon=32, batch=2, cin=3, cout=3, loss="l2")
GEOM = dict(points=256, grid=8, batch=1, loss="l2")

FNO_WIDTH = 32
FNO_MODES = 8
FNO_LAYERS = 4


def fno_cfg(ds, res, prec, stab, cp_rank=0, modes=FNO_MODES, sites=None):
    base = NS if ds == "ns" else DARCY
    return fno.FnoConfig(
        in_channels=base["cin"],
        out_channels=base["cout"],
        width=FNO_WIDTH,
        modes=modes,
        layers=FNO_LAYERS,
        height=res,
        width_grid=res,
        mode=prec,
        stabilizer=stab,
        cp_rank=cp_rank,
        site_precisions=sites,
    )


def build_matrix():
    arts = []

    def add(name, model, dataset, graph, prec, stab, loss, batch, cfg):
        arts.append(Artifact(name, model, dataset, graph, prec, stab, loss, batch, cfg))

    # --- FNO / Navier-Stokes: the main accuracy + stability matrix ------
    for prec, stab in [
        (q.FULL, "none"),
        (q.AMP, "none"),
        (q.MIXED, "tanh"),
        (q.BF16, "tanh"),
        (q.FP8, "tanh"),
        (q.TF32, "none"),
        (q.MIXED, "none"),      # the naive-mixed failure mode (Fig. 10)
        (q.MIXED, "hardclip"),  # Table 3
        (q.MIXED, "sigclip"),   # Table 3
        (q.MIXED, "div"),       # App. B.6
        (q.FULL, "tanh"),       # Table 5: tanh at full precision
    ]:
        add(
            f"fno_ns_r32_{prec}_{stab}_grads",
            "fno", "ns", "grads", prec, stab, NS["loss"], NS["batch"],
            fno_cfg("ns", 32, prec, stab),
        )
    for prec in [q.FULL, q.MIXED]:
        stab = "tanh" if prec == q.MIXED else "none"
        add(
            f"fno_ns_r32_{prec}_{stab}_fwd",
            "fno", "ns", "fwd", prec, stab, NS["loss"], NS["batch"],
            fno_cfg("ns", 32, prec, stab),
        )
    # Zero-shot super-resolution forwards (Table 1): same weights, finer grid.
    for res in [64, 128, 256]:
        for prec in [q.FULL, q.MIXED]:
            stab = "tanh" if prec == q.MIXED else "none"
            add(
                f"fno_ns_r{res}_{prec}_{stab}_fwd",
                "fno", "ns", "fwd", prec, stab, NS["loss"], NS["batch"],
                fno_cfg("ns", res, prec, stab),
            )

    # --- FNO / Darcy ------------------------------------------------------
    for prec, stab in [(q.FULL, "none"), (q.AMP, "none"), (q.MIXED, "tanh")]:
        add(
            f"fno_darcy_r32_{prec}_{stab}_grads",
            "fno", "darcy", "grads", prec, stab, DARCY["loss"], DARCY["batch"],
            fno_cfg("darcy", 32, prec, stab),
        )
    for prec in [q.FULL, q.MIXED]:
        stab = "tanh" if prec == q.MIXED else "none"
        add(
            f"fno_darcy_r32_{prec}_{stab}_fwd",
            "fno", "darcy", "fwd", prec, stab, DARCY["loss"], DARCY["batch"],
            fno_cfg("darcy", 32, prec, stab),
        )
    # Table 4: per-site (fft, contract, ifft) in {full, mixed}^3.
    for bits in range(8):
        f = q.MIXED if bits & 4 else q.FULL
        c = q.MIXED if bits & 2 else q.FULL
        i = q.MIXED if bits & 1 else q.FULL
        tag = "".join("h" if p == q.MIXED else "f" for p in (f, c, i))
        stab = "tanh" if f == q.MIXED else "none"
        add(
            f"fno_darcy_r32_site{tag}_grads",
            "fno", "darcy", "grads", q.MIXED, stab, DARCY["loss"], DARCY["batch"],
            fno_cfg("darcy", 32, q.MIXED, stab, sites=(f, c, i)),
        )
    # Fig. 6 / Fig. 13: CP factorization vs dense.
    for ds in ["ns", "darcy"]:
        for prec in [q.FULL, q.MIXED]:
            stab = "tanh" if prec == q.MIXED else "none"
            add(
                f"fno_{ds}_r32_cp16_{prec}_{stab}_grads",
                "fno", ds, "grads", prec, stab, "h1", 4,
                fno_cfg(ds, 32, prec, stab, cp_rank=16),
            )
    # Fig. 12/14: frequency-mode ablation.
    for modes in [4, 16]:
        for prec in [q.FULL, q.MIXED]:
            stab = "tanh" if prec == q.MIXED else "none"
            add(
                f"fno_darcy_r32_m{modes}_{prec}_{stab}_grads",
                "fno", "darcy", "grads", prec, stab, "h1", 4,
                fno_cfg("darcy", 32, prec, stab, modes=modes),
            )

    # --- U-Net baseline (Table 2) ------------------------------------------
    for ds in ["ns", "darcy"]:
        for prec in [q.FULL, q.AMP]:
            ucfg = unet.UnetConfig(in_channels=1, out_channels=1, width=16,
                                   height=32, width_grid=32, mode=prec)
            add(
                f"unet_{ds}_r32_{prec}_none_grads",
                "unet", ds, "grads", prec, "none", "l2", 4, ucfg,
            )
        ucfg = unet.UnetConfig(in_channels=1, out_channels=1, width=16,
                               height=32, width_grid=32, mode=q.FULL)
        add(f"unet_{ds}_r32_full_none_fwd", "unet", ds, "fwd", q.FULL, "none",
            "l2", 4, ucfg)

    # --- SFNO / spherical SWE ----------------------------------------------
    for prec, stab in [(q.FULL, "none"), (q.AMP, "none"), (q.MIXED, "tanh")]:
        scfg = sfno.SfnoConfig(nlat=SWE["nlat"], nlon=SWE["nlon"], lmax=7,
                               width=24, layers=4, mode=prec, stabilizer=stab)
        add(
            f"sfno_swe_r16_{prec}_{stab}_grads",
            "sfno", "swe", "grads", prec, stab, SWE["loss"], SWE["batch"], scfg,
        )
    for prec in [q.FULL, q.MIXED]:
        stab = "tanh" if prec == q.MIXED else "none"
        scfg = sfno.SfnoConfig(nlat=SWE["nlat"], nlon=SWE["nlon"], lmax=7,
                               width=24, layers=4, mode=prec, stabilizer=stab)
        add(f"sfno_swe_r16_{prec}_{stab}_fwd", "sfno", "swe", "fwd", prec,
            stab, SWE["loss"], SWE["batch"], scfg)

    # --- GINO / Shape-Net Car + Ahmed-body ----------------------------------
    for ds in ["car", "ahmed"]:
        for prec in [q.FULL, q.MIXED]:
            stab = "tanh" if prec == q.MIXED else "none"
            gcfg = gino.GinoConfig(n_points=GEOM["points"], grid=GEOM["grid"],
                                   mode=prec, stabilizer=stab)
            add(
                f"gino_{ds}_p256_{prec}_{stab}_grads",
                "gino", ds, "grads", prec, stab, "l2", 1, gcfg,
            )
        gcfg = gino.GinoConfig(n_points=GEOM["points"], grid=GEOM["grid"],
                               mode=q.FULL, stabilizer="none")
        add(f"gino_{ds}_p256_full_none_fwd", "gino", ds, "fwd", q.FULL,
            "none", "l2", 1, gcfg)

    return arts


# ---------------------------------------------------------------------------
# Lowering
# ---------------------------------------------------------------------------


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def grid_input_specs(art: Artifact):
    cfg = art.cfg
    if art.model in ("fno", "unet"):
        h, w = cfg.height, cfg.width_grid
        cin, cout = cfg.in_channels, cfg.out_channels
    else:  # sfno
        h, w = cfg.nlat, cfg.nlon
        cin, cout = cfg.in_channels, cfg.out_channels
    x = jax.ShapeDtypeStruct((art.batch, cin, h, w), F32)
    y = jax.ShapeDtypeStruct((art.batch, cout, h, w), F32)
    return x, y


def lower_artifact(art: Artifact):
    """Returns (hlo_text, manifest_entry)."""
    if art.model == "gino":
        names, fwd, grads = train_graph.make_gino_graphs(art.cfg)
        cfg = art.cfg
        g3 = cfg.grid**3
        feats = jax.ShapeDtypeStruct((art.batch, cfg.n_points, cfg.in_features), F32)
        to_g = jax.ShapeDtypeStruct((art.batch, g3, cfg.n_points), F32)
        from_g = jax.ShapeDtypeStruct((art.batch, cfg.n_points, g3), F32)
        y = jax.ShapeDtypeStruct((art.batch, cfg.n_points), F32)
        extra_fwd = [("feats", feats), ("to_grid", to_g), ("from_grid", from_g)]
        extra_grads = extra_fwd + [("target", y), ("loss_scale", jax.ShapeDtypeStruct((), F32))]
        specs = [(n, tuple(s), std) for n, s, std in gino.param_specs(art.cfg)]
    else:
        names, fwd, grads = train_graph.make_grid_graphs(art.model, art.cfg, art.loss)
        x, y = grid_input_specs(art)
        extra_fwd = [("x", x)]
        extra_grads = [("x", x), ("target", y), ("loss_scale", jax.ShapeDtypeStruct((), F32))]
        mod = {"fno": fno, "sfno": sfno, "unet": unet}[art.model]
        specs = [(n, tuple(s), std) for n, s, std in mod.param_specs(art.cfg)]

    pspecs = train_graph.example_param_arrays(art.model, art.cfg)
    if art.graph == "fwd":
        fn, extra = fwd, extra_fwd
    else:
        fn, extra = grads, extra_grads
    args = pspecs + [s for _, s in extra]
    lowered = jax.jit(fn).lower(*args)
    text = to_hlo_text(lowered)

    entry = {
        "name": art.name,
        "file": art.name + ".hlo.txt",
        "model": art.model,
        "dataset": art.dataset,
        "graph": art.graph,
        "precision": art.precision,
        "stabilizer": art.stabilizer,
        "loss": art.loss,
        "batch": art.batch,
        "params": [
            {"name": n, "shape": list(s), "std": float(std)} for n, s, std in specs
        ],
        "extra_inputs": [
            {"name": n, "shape": list(s.shape)} for n, s in extra
        ],
        "config": _cfg_summary(art),
    }
    return text, entry


def _cfg_summary(art: Artifact):
    c = art.cfg
    out = {}
    for field in (
        "width", "modes", "layers", "height", "width_grid", "cp_rank",
        "nlat", "nlon", "lmax", "n_points", "grid", "in_channels",
        "out_channels",
    ):
        if hasattr(c, field):
            out[field] = getattr(c, field)
    if getattr(c, "site_precisions", None):
        out["site_precisions"] = list(c.site_precisions)
    return out


def inputs_hash():
    h = hashlib.sha256()
    root = os.path.dirname(os.path.abspath(__file__))
    for dirpath, _, files in sorted(os.walk(root)):
        if "__pycache__" in dirpath:
            continue
        for f in sorted(files):
            if f.endswith(".py"):
                h.update(open(os.path.join(dirpath, f), "rb").read())
    return h.hexdigest()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None, help="substring filter")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    digest = inputs_hash()
    hash_file = os.path.join(args.out_dir, ".inputs_hash")
    manifest_file = os.path.join(args.out_dir, "manifest.json")
    if (
        not args.force
        and not args.only
        and os.path.exists(hash_file)
        and os.path.exists(manifest_file)
        and open(hash_file).read().strip() == digest
    ):
        print("artifacts up to date (hash match); skipping")
        return

    arts = build_matrix()
    if args.only:
        arts = [a for a in arts if args.only in a.name]
    manifest = {"version": 1, "artifacts": []}
    t_start = time.time()
    for i, art in enumerate(arts):
        t0 = time.time()
        text, entry = lower_artifact(art)
        path = os.path.join(args.out_dir, entry["file"])
        with open(path, "w") as f:
            f.write(text)
        manifest["artifacts"].append(entry)
        print(
            f"[{i + 1}/{len(arts)}] {art.name}: {len(text) / 1e6:.2f} MB "
            f"({time.time() - t0:.1f}s)",
            flush=True,
        )
    if not args.only:
        with open(manifest_file, "w") as f:
            json.dump(manifest, f, indent=1)
        with open(hash_file, "w") as f:
            f.write(digest)
    else:
        print("(--only: manifest/hash not updated)")
    print(f"done: {len(arts)} artifacts in {time.time() - t_start:.0f}s")


if __name__ == "__main__":
    sys.exit(main())
