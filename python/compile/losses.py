"""Operator-learning losses: relative L2 and relative H1 (Sobolev).

The paper trains with H1 on Navier-Stokes/Darcy (Fig. 5) and reports both
H1 and L2. H1 is computed spectrally: ||u||_H1^2 = sum_k (1 + |k|^2)
|u_hat_k|^2 with k the integer frequency lattice — matching the
neuraloperator reference implementation up to normalization.
"""

import jax.numpy as jnp


def relative_l2(pred, target, eps=1e-12):
    """Mean over batch of ||pred - target||_2 / ||target||_2."""
    b = pred.shape[0]
    diff = (pred - target).reshape(b, -1)
    tgt = target.reshape(b, -1)
    num = jnp.sqrt(jnp.sum(diff**2, axis=1) + eps)
    den = jnp.sqrt(jnp.sum(tgt**2, axis=1) + eps)
    return jnp.mean(num / den)


def _sobolev_weights(h, w):
    ky = jnp.fft.fftfreq(h) * h
    kx = jnp.fft.fftfreq(w) * w
    k2 = ky[:, None] ** 2 + kx[None, :] ** 2
    return 1.0 + k2


def relative_h1(pred, target, eps=1e-12):
    """Mean over batch of the relative H1 distance (spectral Sobolev)."""
    b = pred.shape[0]
    h, w = pred.shape[-2], pred.shape[-1]
    wgt = _sobolev_weights(h, w)
    ph = jnp.fft.fft2(pred.astype(jnp.complex64))
    th = jnp.fft.fft2(target.astype(jnp.complex64))
    num = jnp.sum(wgt * jnp.abs(ph - th) ** 2, axis=(-2, -1))
    den = jnp.sum(wgt * jnp.abs(th) ** 2, axis=(-2, -1))
    num = jnp.sum(num.reshape(b, -1), axis=1)
    den = jnp.sum(den.reshape(b, -1), axis=1)
    return jnp.mean(jnp.sqrt((num + eps) / (den + eps)))
