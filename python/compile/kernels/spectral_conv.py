"""L1 — Pallas kernels for the FNO spectral-weight tensor contraction.

This is the paper's compute hot-spot: profiling (App. B.4, Fig. 9) shows
the complex tensor contraction inside the FNO block accounts for 4 of the
5 most expensive GPU kernels. Here it is implemented as a Pallas kernel in
the *view-as-real Option C* form of App. B.12.1: the complex multiply is
decomposed into real multiply-adds on the re/im planes, with low-dimension
bookkeeping kept in complex form at L2.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper's CUDA
view targets tensor-core GEMMs over (b·modes, i)x(i, o) tiles; on TPU the
same insight maps to MXU-shaped dots per mode-tile with the HBM->VMEM
schedule expressed via BlockSpec:

* the grid iterates over the truncated kx modes — each program instance
  holds one (b, i, KY) activation tile and one (i, o, KY) weight tile in
  VMEM and issues 4 real dot_generals (the view-as-real complex product);
* VMEM footprint per instance (f32): (b*i + i*o + 2*b*o) * KY * 4 bytes *
  2 planes — e.g. b=8, i=o=32, KY=17: ~0.6 MiB, well under the ~16 MiB
  VMEM budget, leaving room for double buffering of the next kx tile;
* in half precision the same tiles halve, which is exactly the memory
  saving the paper measures (and what lets batch size double).

Kernels must be lowered with ``interpret=True``: the CPU PJRT plugin
cannot execute Mosaic custom-calls (see /opt/xla-example/README.md).

Autodiff: ``pallas_call`` is not auto-differentiable, so the public entry
points carry a ``custom_vjp`` whose backward pass is the transposed pair
contraction (itself expressed with einsum at L2 — the backward matmuls
fuse fine under XLA), with cotangents rounded per the precision mode so
the backward pass is emulated at the same precision as a true half-
precision training run.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from compile import quantize as q


def _rounder(mode):
    return q._SPECTRAL_ROUNDERS[mode]


def _contract_kernel_2d(xr_ref, xi_ref, wr_ref, wi_ref, or_ref, oi_ref, *, mode):
    """One kx-tile: (b,i,1,ky) x (i,o,1,ky) -> (b,o,1,ky) complex."""
    rnd = _rounder(mode)
    xr = rnd(xr_ref[...])
    xi = rnd(xi_ref[...])
    wr = rnd(wr_ref[...])
    wi = rnd(wi_ref[...])
    # 4 real contractions (view-as-real complex product). dot over i.
    rr = jnp.einsum("bixy,ioxy->boxy", xr, wr)
    ii = jnp.einsum("bixy,ioxy->boxy", xi, wi)
    ri = jnp.einsum("bixy,ioxy->boxy", xr, wi)
    ir = jnp.einsum("bixy,ioxy->boxy", xi, wr)
    or_ref[...] = rnd(rr - ii)
    oi_ref[...] = rnd(ri + ir)


# VMEM budget (elements) under which the whole contraction fits one kernel
# instance: (b*i + i*o + 2*b*o) * KX * KY * 2 planes * 4B must stay under
# ~16 MiB. Perf note (EXPERIMENTS.md §Perf L1/L2): the single-instance form
# avoids interpret-mode's per-grid-step loop — 5.3x faster at FNO shapes on
# the CPU backend — while the kx-tiled form below remains the TPU-shaped
# HBM->VMEM schedule for larger-than-VMEM problems.
_VMEM_ELEM_BUDGET = 2 * 1024 * 1024


def _pallas_contract_2d(xr, xi, wr, wi, mode):
    b, ci, kx, ky = xr.shape
    _, co, _, _ = wr.shape
    out_shape = [
        jax.ShapeDtypeStruct((b, co, kx, ky), xr.dtype),
        jax.ShapeDtypeStruct((b, co, kx, ky), xr.dtype),
    ]
    kern = functools.partial(_contract_kernel_2d, mode=mode)
    vmem_elems = 2 * (b * ci + ci * co + 2 * b * co) * kx * ky
    if vmem_elems <= _VMEM_ELEM_BUDGET:
        return pl.pallas_call(kern, out_shape=out_shape, interpret=True)(
            xr, xi, wr, wi
        )
    return pl.pallas_call(
        kern,
        grid=(kx,),
        in_specs=[
            pl.BlockSpec((b, ci, 1, ky), lambda gx: (0, 0, gx, 0)),
            pl.BlockSpec((b, ci, 1, ky), lambda gx: (0, 0, gx, 0)),
            pl.BlockSpec((ci, co, 1, ky), lambda gx: (0, 0, gx, 0)),
            pl.BlockSpec((ci, co, 1, ky), lambda gx: (0, 0, gx, 0)),
        ],
        out_specs=[
            pl.BlockSpec((b, co, 1, ky), lambda gx: (0, 0, gx, 0)),
            pl.BlockSpec((b, co, 1, ky), lambda gx: (0, 0, gx, 0)),
        ],
        out_shape=out_shape,
        interpret=True,
    )(xr, xi, wr, wi)


@functools.partial(jax.custom_vjp, nondiff_argnums=(4,))
def spectral_contract(xr, xi, wr, wi, mode=q.FULL):
    """Complex 2-D spectral contraction out = x . w over the channel dim.

    Shapes: x (b,i,kx,ky) pairs, w (i,o,kx,ky) pairs -> (b,o,kx,ky) pairs.
    """
    return _pallas_contract_2d(xr, xi, wr, wi, mode)


def _sc_fwd(xr, xi, wr, wi, mode):
    out = _pallas_contract_2d(xr, xi, wr, wi, mode)
    return out, (xr, xi, wr, wi)


def _sc_bwd(mode, res, g):
    xr, xi, wr, wi = res
    gor, goi = g
    rnd = _rounder(mode)
    gor = rnd(gor)
    goi = rnd(goi)
    # Transposed pair contractions (derived in the module docstring).
    gxr = jnp.einsum("boxy,ioxy->bixy", gor, wr) + jnp.einsum(
        "boxy,ioxy->bixy", goi, wi
    )
    gxi = -jnp.einsum("boxy,ioxy->bixy", gor, wi) + jnp.einsum(
        "boxy,ioxy->bixy", goi, wr
    )
    gwr = jnp.einsum("bixy,boxy->ioxy", xr, gor) + jnp.einsum(
        "bixy,boxy->ioxy", xi, goi
    )
    gwi = -jnp.einsum("bixy,boxy->ioxy", xi, gor) + jnp.einsum(
        "bixy,boxy->ioxy", xr, goi
    )
    return rnd(gxr), rnd(gxi), rnd(gwr), rnd(gwi)


spectral_contract.defvjp(_sc_fwd, _sc_bwd)


def _contract_kernel_3d(xr_ref, xi_ref, wr_ref, wi_ref, or_ref, oi_ref, *, mode):
    rnd = _rounder(mode)
    xr = rnd(xr_ref[...])
    xi = rnd(xi_ref[...])
    wr = rnd(wr_ref[...])
    wi = rnd(wi_ref[...])
    rr = jnp.einsum("bixyz,ioxyz->boxyz", xr, wr)
    ii = jnp.einsum("bixyz,ioxyz->boxyz", xi, wi)
    ri = jnp.einsum("bixyz,ioxyz->boxyz", xr, wi)
    ir = jnp.einsum("bixyz,ioxyz->boxyz", xi, wr)
    or_ref[...] = rnd(rr - ii)
    oi_ref[...] = rnd(ri + ir)


def _pallas_contract_3d(xr, xi, wr, wi, mode):
    b, ci, kx, ky, kz = xr.shape
    _, co, _, _, _ = wr.shape
    out_shape = [
        jax.ShapeDtypeStruct((b, co, kx, ky, kz), xr.dtype),
        jax.ShapeDtypeStruct((b, co, kx, ky, kz), xr.dtype),
    ]
    kern = functools.partial(_contract_kernel_3d, mode=mode)
    vmem_elems = 2 * (b * ci + ci * co + 2 * b * co) * kx * ky * kz
    if vmem_elems <= _VMEM_ELEM_BUDGET:
        return pl.pallas_call(kern, out_shape=out_shape, interpret=True)(
            xr, xi, wr, wi
        )
    return pl.pallas_call(
        kern,
        grid=(kx,),
        in_specs=[
            pl.BlockSpec((b, ci, 1, ky, kz), lambda gx: (0, 0, gx, 0, 0)),
            pl.BlockSpec((b, ci, 1, ky, kz), lambda gx: (0, 0, gx, 0, 0)),
            pl.BlockSpec((ci, co, 1, ky, kz), lambda gx: (0, 0, gx, 0, 0)),
            pl.BlockSpec((ci, co, 1, ky, kz), lambda gx: (0, 0, gx, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((b, co, 1, ky, kz), lambda gx: (0, 0, gx, 0, 0)),
            pl.BlockSpec((b, co, 1, ky, kz), lambda gx: (0, 0, gx, 0, 0)),
        ],
        out_shape=out_shape,
        interpret=True,
    )(xr, xi, wr, wi)


@functools.partial(jax.custom_vjp, nondiff_argnums=(4,))
def spectral_contract_3d(xr, xi, wr, wi, mode=q.FULL):
    """Complex 3-D spectral contraction (GINO's latent FNO)."""
    return _pallas_contract_3d(xr, xi, wr, wi, mode)


def _sc3_fwd(xr, xi, wr, wi, mode):
    return _pallas_contract_3d(xr, xi, wr, wi, mode), (xr, xi, wr, wi)


def _sc3_bwd(mode, res, g):
    xr, xi, wr, wi = res
    gor, goi = g
    rnd = _rounder(mode)
    gor = rnd(gor)
    goi = rnd(goi)
    gxr = jnp.einsum("boxyz,ioxyz->bixyz", gor, wr) + jnp.einsum(
        "boxyz,ioxyz->bixyz", goi, wi
    )
    gxi = -jnp.einsum("boxyz,ioxyz->bixyz", gor, wi) + jnp.einsum(
        "boxyz,ioxyz->bixyz", goi, wr
    )
    gwr = jnp.einsum("bixyz,boxyz->ioxyz", xr, gor) + jnp.einsum(
        "bixyz,boxyz->ioxyz", xi, goi
    )
    gwi = -jnp.einsum("bixyz,boxyz->ioxyz", xi, gor) + jnp.einsum(
        "bixyz,boxyz->ioxyz", xr, goi
    )
    return rnd(gxr), rnd(gxi), rnd(gwr), rnd(gwi)


spectral_contract_3d.defvjp(_sc3_fwd, _sc3_bwd)


def cp_contract(xr, xi, lam, fir, fii, for_, foi, fxr, fxi, fyr, fyi, mode=q.FULL):
    """CP-factorized (TFNO) contraction with the paper's memory-greedy
    sub-expression order: merge the rank-indexed factor matrices first
    (tiny intermediates), reconstruct the dense spectral weight last, and
    run the final high-dimensional contraction in the Pallas kernel.

    Each intermediate is rounded per `mode`, matching the "each einsum step
    in half precision" design of §4.2.
    """
    rnd = q._SPECTRAL_CASTS[mode]  # custom-vjp cast: rounds fwd and bwd

    def c(z):
        return rnd(jnp.real(z)) + 1j * rnd(jnp.imag(z))

    fi = fir + 1j * fii
    fo = for_ + 1j * foi
    fx = fxr + 1j * fxi
    fy = fyr + 1j * fyi
    # Greedy order (smallest intermediates first): lam*fi -> io -> ioy -> ioxy.
    t = c(jnp.einsum("r,ir->ir", lam.astype(fi.dtype), fi))
    t = c(jnp.einsum("ir,or->ior", t, fo))
    t = c(jnp.einsum("ior,yr->ioyr", t, fy))
    w = c(jnp.einsum("ioyr,xr->ioxy", t, fx))
    return spectral_contract(xr, xi, jnp.real(w), jnp.imag(w), mode)
