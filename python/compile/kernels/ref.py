"""Pure-jnp oracles for the Pallas kernels.

Every kernel in this package must match its oracle to float tolerance
under pytest (python/tests/test_kernel.py) — this is the L1 correctness
contract of the three-layer architecture.
"""

import jax.numpy as jnp


def spectral_contract_ref(xr, xi, wr, wi):
    """Complex spectral contraction, viewed as real pairs.

    out[b,o,kx,ky] = sum_i x[b,i,kx,ky] * w[i,o,kx,ky]  (complex)

    Args are the real/imag planes; returns (out_re, out_im).
    """
    orr = jnp.einsum("bixy,ioxy->boxy", xr, wr) - jnp.einsum(
        "bixy,ioxy->boxy", xi, wi
    )
    oi = jnp.einsum("bixy,ioxy->boxy", xr, wi) + jnp.einsum(
        "bixy,ioxy->boxy", xi, wr
    )
    return orr, oi


def spectral_contract_3d_ref(xr, xi, wr, wi):
    """3-D variant: out[b,o,kx,ky,kz] = sum_i x * w (complex)."""
    orr = jnp.einsum("bixyz,ioxyz->boxyz", xr, wr) - jnp.einsum(
        "bixyz,ioxyz->boxyz", xi, wi
    )
    oi = jnp.einsum("bixyz,ioxyz->boxyz", xr, wi) + jnp.einsum(
        "bixyz,ioxyz->boxyz", xi, wr
    )
    return orr, oi


def cp_contract_ref(xr, xi, lam, fir, fii, for_, foi, fxr, fxi, fyr, fyi):
    """CP-factorized contraction (TFNO):

    out[b,o,x,y] = sum_{i,r} x[b,i,x,y] lam[r] fi[i,r] fo[o,r] fx[x,r] fy[y,r]

    with x and all factors complex (given as re/im planes; lam real).
    Reference implementation reconstructs the dense weight first.
    """
    fi = fir + 1j * fii
    fo = for_ + 1j * foi
    fx = fxr + 1j * fxi
    fy = fyr + 1j * fyi
    w = jnp.einsum("r,ir,or,xr,yr->ioxy", lam.astype(fi.dtype), fi, fo, fx, fy)
    x = xr + 1j * xi
    out = jnp.einsum("bixy,ioxy->boxy", x, w)
    return jnp.real(out), jnp.imag(out)


def tanh_stabilize_ref(v):
    """The paper's §4.3 pre-activation stabilizer."""
    return jnp.tanh(v)
