"""Loss + gradient graphs for AOT export.

Each model gets two exported graphs:

* ``fwd``   — (params..., inputs...) -> (pred,)
* ``grads`` — (params..., inputs..., target, loss_scale) ->
              (loss, scaled_grads...)

``loss_scale`` is a runtime scalar: the graph differentiates
``loss * loss_scale`` so the Rust-side ``amp::GradScaler`` can implement
dynamic loss scaling (App. B.5) without re-exporting; the unscaled loss is
returned for logging. The optimizer (Adam with fp32 master weights) lives
in Rust — gradients cross the PJRT boundary as plain f32 tensors.
"""

import jax
import jax.numpy as jnp

from compile import losses
from compile.models import fno, gino, sfno, unet


def flatten_params(params, names):
    return [params[n] for n in names]


def unflatten_params(flat, names):
    return dict(zip(names, flat))


def make_grid_graphs(model, cfg, loss_name):
    """Graphs for grid models (FNO / TFNO / SFNO / U-Net).

    Returns (names, fwd_fn, grads_fn) where the fns take flat params.
    """
    if model == "fno":
        mod = fno
    elif model == "sfno":
        mod = sfno
    elif model == "unet":
        mod = unet
    else:
        raise ValueError(model)
    names = [n for n, _, _ in mod.param_specs(cfg)]
    loss_fn = losses.relative_h1 if loss_name == "h1" else losses.relative_l2

    def fwd(*args):
        flat, x = list(args[:-1]), args[-1]
        params = unflatten_params(flat, names)
        return (mod.forward(params, x, cfg),)

    def grads(*args):
        flat = list(args[:-3])
        x, y, loss_scale = args[-3], args[-2], args[-1]

        def scalar_loss(flat_params):
            params = unflatten_params(flat_params, names)
            pred = mod.forward(params, x, cfg)
            return loss_fn(pred, y)

        loss, g = jax.value_and_grad(
            lambda fp: scalar_loss(fp) * loss_scale
        )(flat)
        return (loss / loss_scale, *g)

    return names, fwd, grads


def make_gino_graphs(cfg):
    """Graphs for GINO (extra inputs: interpolation matrices)."""
    names = [n for n, _, _ in gino.param_specs(cfg)]

    def fwd(*args):
        flat = list(args[:-3])
        feats, to_grid, from_grid = args[-3], args[-2], args[-1]
        params = unflatten_params(flat, names)
        return (gino.forward(params, feats, to_grid, from_grid, cfg),)

    def grads(*args):
        flat = list(args[:-5])
        feats, to_grid, from_grid, y, loss_scale = args[-5:]

        def scalar_loss(fp):
            params = unflatten_params(fp, names)
            pred = gino.forward(params, feats, to_grid, from_grid, cfg)
            return losses.relative_l2(pred[:, None, :], y[:, None, :])

        loss, g = jax.value_and_grad(
            lambda fp: scalar_loss(fp) * loss_scale
        )(flat)
        return (loss / loss_scale, *g)

    return names, fwd, grads


def example_param_arrays(model, cfg):
    """ShapeDtypeStructs for the flat parameter list."""
    mod = {"fno": fno, "sfno": sfno, "unet": unet, "gino": gino}[model]
    return [
        jax.ShapeDtypeStruct(shape, jnp.float32)
        for _, shape, _ in mod.param_specs(cfg)
    ]
