"""Emulated-cast correctness, cross-checked against the Rust softfloat.

If `artifacts/fp_vectors.json` exists (dumped by `mpno dump-fp-vectors`),
every (input, mode) pair is checked bit-for-bit against the Rust
implementation — the two emulations must agree exactly for the memory
model and the theory experiments to be consistent across layers.
"""

import json
import os

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import quantize as q


def test_f16_rounding_constants():
    xs = jnp.array([0.0, 1.0, 65504.0, 65520.0, 2049.0, 1e-8])
    out = np.asarray(q.spectral_cast(xs, q.MIXED))
    assert out[0] == 0.0
    assert out[1] == 1.0
    assert out[2] == 65504.0
    assert np.isinf(out[3])  # past the cliff
    assert out[4] == 2048.0  # RNE
    assert out[5] == np.float32(np.float16(1e-8))


def test_bf16_coarser_than_f16_in_range():
    xs = jnp.linspace(0.5, 2.0, 101)
    e16 = np.abs(np.asarray(q.spectral_cast(xs, q.MIXED)) - np.asarray(xs)).max()
    ebf = np.abs(np.asarray(q.spectral_cast(xs, q.BF16)) - np.asarray(xs)).max()
    assert ebf > e16


def test_tf32_matches_reference_bit_pattern():
    xs = np.array([1.0 + 2**-12, 1.0 + 3 * 2**-11, 3.14159265, -2.71828], np.float32)
    got = np.asarray(q.spectral_cast(jnp.asarray(xs), q.TF32))
    # Reference: round mantissa to 10 bits (RNE) via float64 arithmetic.
    def tf32_ref(x):
        if x == 0 or not np.isfinite(x):
            return x
        bits = np.float32(x).view(np.uint32)
        lsb = (bits >> np.uint32(13)) & np.uint32(1)
        r = (bits + np.uint32(0xFFF) + lsb) & np.uint32(0xFFFFE000)
        return r.view(np.float32)

    want = np.array([tf32_ref(x) for x in xs])
    np.testing.assert_array_equal(got, want)


def test_fp8_clips_at_e5m2_range():
    xs = jnp.array([1.0, 60000.0, 70000.0, -70000.0])
    out = np.asarray(q.spectral_cast(xs, q.FP8))
    assert out[0] == 1.0
    assert out[1] <= q.E5M2_MAX
    assert out[2] == q.E5M2_MAX
    assert out[3] == -q.E5M2_MAX


def test_amp_leaves_spectral_untouched():
    xs = jnp.array([1.0 + 2.0**-20])
    assert float(q.spectral_cast(xs, q.AMP)[0]) == float(xs[0])
    # ...but rounds dense values.
    assert float(q.dense_cast(xs, q.AMP)[0]) == 1.0


def test_complex_cast_per_component():
    z = jnp.array([1.0 + 2.0**-20 + 1j * (2.0 + 2.0**-18)], jnp.complex64)
    out = q.spectral_cast(z, q.MIXED)
    assert float(jnp.real(out)[0]) == 1.0
    assert float(jnp.imag(out)[0]) == 2.0


@settings(max_examples=50, deadline=None)
@given(st.floats(-6e4, 6e4, allow_nan=False))
def test_f16_idempotent(x):
    a = q.spectral_cast(jnp.float32(x), q.MIXED)
    b = q.spectral_cast(a, q.MIXED)
    assert float(a) == float(b)


@pytest.mark.skipif(
    not os.path.exists(os.path.join(os.path.dirname(__file__), "../../artifacts/fp_vectors.json")),
    reason="run `mpno dump-fp-vectors` first for the cross-layer bit check",
)
def test_bit_exact_vs_rust_softfloat():
    path = os.path.join(os.path.dirname(__file__), "../../artifacts/fp_vectors.json")
    vectors = json.load(open(path))
    mode_map = {"mixed": q.MIXED, "bf16": q.BF16, "fp8": q.FP8, "tf32": q.TF32}
    for rec in vectors:
        mode = mode_map[rec["mode"]]
        x = jnp.asarray(np.array(rec["input"], np.float32))
        got = np.asarray(q.spectral_cast(x, mode))
        want = np.array(rec["output"], np.float32)
        np.testing.assert_array_equal(
            got, want, err_msg=f"mode={rec['mode']} diverges from Rust softfloat"
        )
