"""L1 correctness: Pallas kernels vs pure-jnp oracles.

This is the core correctness signal of the compile path — hypothesis
sweeps shapes and precision modes and asserts allclose against ref.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import quantize as q
from compile.kernels import ref
from compile.kernels import spectral_conv as sc


def _rand(key, shape):
    return jax.random.normal(key, shape, jnp.float32)


def _planes(seed, b, ci, co, *spatial):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    xr = _rand(ks[0], (b, ci) + spatial)
    xi = _rand(ks[1], (b, ci) + spatial)
    wr = _rand(ks[2], (ci, co) + spatial)
    wi = _rand(ks[3], (ci, co) + spatial)
    return xr, xi, wr, wi


@settings(max_examples=12, deadline=None)
@given(
    b=st.integers(1, 3),
    ci=st.integers(1, 6),
    co=st.integers(1, 6),
    kx=st.integers(1, 6),
    ky=st.integers(1, 6),
    seed=st.integers(0, 2**16),
)
def test_contract_2d_matches_ref(b, ci, co, kx, ky, seed):
    xr, xi, wr, wi = _planes(seed, b, ci, co, kx, ky)
    got_r, got_i = sc.spectral_contract(xr, xi, wr, wi, q.FULL)
    want_r, want_i = ref.spectral_contract_ref(xr, xi, wr, wi)
    np.testing.assert_allclose(got_r, want_r, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(got_i, want_i, rtol=1e-5, atol=1e-5)


@settings(max_examples=6, deadline=None)
@given(
    b=st.integers(1, 2),
    ci=st.integers(1, 4),
    co=st.integers(1, 4),
    k=st.integers(1, 4),
    seed=st.integers(0, 2**16),
)
def test_contract_3d_matches_ref(b, ci, co, k, seed):
    xr, xi, wr, wi = _planes(seed, b, ci, co, k, k, k)
    got_r, got_i = sc.spectral_contract_3d(xr, xi, wr, wi, q.FULL)
    want_r, want_i = ref.spectral_contract_3d_ref(xr, xi, wr, wi)
    np.testing.assert_allclose(got_r, want_r, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(got_i, want_i, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("mode", [q.MIXED, q.BF16, q.TF32])
def test_reduced_precision_error_is_bounded(mode):
    """Theorem 3.2 in kernel form: the half-precision contraction's
    relative error stays at the format's epsilon scale."""
    xr, xi, wr, wi = _planes(7, 2, 8, 8, 6, 6)
    got_r, _ = sc.spectral_contract(xr, xi, wr, wi, mode)
    want_r, _ = ref.spectral_contract_ref(xr, xi, wr, wi)
    rel = float(jnp.linalg.norm(got_r - want_r) / jnp.linalg.norm(want_r))
    eps = {q.MIXED: 1e-3, q.BF16: 8e-3, q.TF32: 1e-3}[mode]
    assert 0 < rel < 30 * eps, f"{mode}: rel={rel}"


def test_mixed_less_accurate_than_full_more_than_tf32_noise():
    xr, xi, wr, wi = _planes(3, 2, 8, 8, 5, 5)
    full_r, _ = sc.spectral_contract(xr, xi, wr, wi, q.FULL)
    want_r, _ = ref.spectral_contract_ref(xr, xi, wr, wi)
    assert float(jnp.abs(full_r - want_r).max()) < 1e-4


def test_gradients_match_ref():
    xr, xi, wr, wi = _planes(11, 2, 4, 5, 3, 3)

    def loss_pallas(wr):
        a, b = sc.spectral_contract(xr, xi, wr, wi, q.FULL)
        return jnp.sum(a**2) + jnp.sum(jnp.abs(b))

    def loss_ref(wr):
        a, b = ref.spectral_contract_ref(xr, xi, wr, wi)
        return jnp.sum(a**2) + jnp.sum(jnp.abs(b))

    g1 = jax.grad(loss_pallas)(wr)
    g2 = jax.grad(loss_ref)(wr)
    np.testing.assert_allclose(g1, g2, rtol=1e-4, atol=1e-4)


def test_gradient_rounding_in_mixed_mode():
    """The custom-vjp backward must round cotangents: tiny gradient
    components below f16 resolution vanish relative to full mode."""
    xr, xi, wr, wi = _planes(13, 1, 2, 2, 2, 2)

    def loss(mode):
        def f(x):
            a, _ = sc.spectral_contract(x, xi, wr, wi, mode)
            return jnp.sum(a)

        return jax.grad(f)(xr)

    g_full = loss(q.FULL)
    g_mixed = loss(q.MIXED)
    # Mixed grads are f16-quantized values.
    assert np.allclose(
        np.asarray(g_mixed), np.asarray(g_mixed).astype(np.float16).astype(np.float32)
    )
    assert not np.allclose(np.asarray(g_full), np.asarray(g_mixed), atol=0)


def test_cp_contract_matches_ref():
    b, ci, co, kx, ky, r = 2, 3, 4, 4, 4, 3
    ks = jax.random.split(jax.random.PRNGKey(5), 11)
    xr, xi = _rand(ks[0], (b, ci, kx, ky)), _rand(ks[1], (b, ci, kx, ky))
    lam = _rand(ks[2], (r,))
    fir, fii = _rand(ks[3], (ci, r)), _rand(ks[4], (ci, r))
    for_, foi = _rand(ks[5], (co, r)), _rand(ks[6], (co, r))
    fxr, fxi = _rand(ks[7], (kx, r)), _rand(ks[8], (kx, r))
    fyr, fyi = _rand(ks[9], (ky, r)), _rand(ks[10], (ky, r))
    got_r, got_i = sc.cp_contract(
        xr, xi, lam, fir, fii, for_, foi, fxr, fxi, fyr, fyi, q.FULL
    )
    want_r, want_i = ref.cp_contract_ref(
        xr, xi, lam, fir, fii, for_, foi, fxr, fxi, fyr, fyi
    )
    np.testing.assert_allclose(got_r, want_r, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(got_i, want_i, rtol=1e-4, atol=1e-4)


def test_f16_overflow_propagates_in_mixed():
    """65504 is the cliff: values past it become inf in mixed mode (the
    §4.3 failure naive mixed-precision hits) but stay finite in full."""
    xr = jnp.full((1, 1, 1, 1), 7e4, jnp.float32)
    xi = jnp.zeros_like(xr)
    wr = jnp.ones((1, 1, 1, 1), jnp.float32)
    wi = jnp.zeros_like(wr)
    full_r, _ = sc.spectral_contract(xr, xi, wr, wi, q.FULL)
    mixed_r, _ = sc.spectral_contract(xr, xi, wr, wi, q.MIXED)
    assert np.isfinite(np.asarray(full_r)).all()
    assert not np.isfinite(np.asarray(mixed_r)).all()
