"""L2 model checks: shapes, precision modes, resolution invariance,
stabilizer behaviour, SHT correctness, losses."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import losses
from compile import quantize as q
from compile.models import fno, gino, sfno, unet


def small_fno(mode=q.FULL, stab="none", cp_rank=0, res=16):
    return fno.FnoConfig(
        width=8, modes=4, layers=2, height=res, width_grid=res,
        mode=mode, stabilizer=stab, cp_rank=cp_rank,
    )


def test_fno_shapes_all_modes():
    for mode in q.ALL_MODES:
        cfg = small_fno(mode=mode, stab="tanh" if mode != q.FULL else "none")
        params = fno.init_params(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 1, 16, 16))
        y = fno.forward(params, x, cfg)
        assert y.shape == (2, 1, 16, 16)
        assert np.isfinite(np.asarray(y)).all(), mode


def test_fno_resolution_invariance():
    """Discretization convergence: the same weights evaluate at any
    resolution (the property zero-shot super-resolution relies on), and
    on a band-limited input the outputs agree across resolutions."""
    cfg16 = small_fno(res=16)
    cfg32 = small_fno(res=32)
    params = fno.init_params(jax.random.PRNGKey(0), cfg16)

    def field(res):
        ys = jnp.linspace(0, 1, res, endpoint=False)
        xs = jnp.linspace(0, 1, res, endpoint=False)
        gy, gx = jnp.meshgrid(ys, xs, indexing="ij")
        f = jnp.sin(2 * jnp.pi * gx) + 0.5 * jnp.cos(2 * jnp.pi * gy)
        return f[None, None]

    y16 = fno.forward(params, field(16), cfg16)
    y32 = fno.forward(params, field(32), cfg32)
    # Compare on the common (coarse) grid.
    y32_sub = y32[:, :, ::2, ::2]
    rel = float(jnp.linalg.norm(y16 - y32_sub) / jnp.linalg.norm(y16))
    assert rel < 0.15, f"resolution transfer rel err {rel}"


def test_tanh_stabilizer_rescues_mixed_precision():
    """The §4.3 story end-to-end: un-normalized inputs overflow the f16
    FFT (DC bin accumulates the whole grid) and kill the naive mixed
    model, while the tanh pre-activation keeps it finite."""
    x = 500.0 * jax.random.normal(jax.random.PRNGKey(1), (1, 1, 16, 16))
    outs = {}
    for stab in ["none", "tanh"]:
        cfg = small_fno(mode=q.MIXED, stab=stab)
        params = fno.init_params(jax.random.PRNGKey(0), cfg)
        outs[stab] = np.asarray(fno.forward(params, x, cfg))
    assert not np.isfinite(outs["none"]).all(), "naive mixed should overflow"
    assert np.isfinite(outs["tanh"]).all(), "tanh must stabilize"


def test_cp_and_dense_agree_at_init_scale():
    """CP with full rank reconstructs some dense weight; both paths must
    at least produce finite, same-shaped outputs."""
    cfg = small_fno(cp_rank=4)
    params = fno.init_params(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 1, 16, 16))
    y = fno.forward(params, x, cfg)
    assert y.shape == (2, 1, 16, 16)
    assert np.isfinite(np.asarray(y)).all()


def test_unet_shapes():
    cfg = unet.UnetConfig(width=8, height=16, width_grid=16)
    params = unet.init_params(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 1, 16, 16))
    y = unet.forward(params, x, cfg)
    assert y.shape == (2, 1, 16, 16)


def test_sht_roundtrip_band_limited():
    """Analysis -> synthesis on the equiangular grid must reproduce a
    band-limited field (quadrature is approximate; tolerance reflects it)."""
    nlat, nlon, lmax = 16, 32, 7
    theta = jnp.pi * (jnp.arange(nlat) + 0.5) / nlat
    lam = 2 * jnp.pi * jnp.arange(nlon) / nlon
    th, lm = jnp.meshgrid(theta, lam, indexing="ij")
    # Y_2^1-flavoured smooth field.
    f = jnp.sin(th) * jnp.cos(th) * jnp.cos(lm) + 0.3 * jnp.cos(th) ** 2
    v = f[None, None]
    a = sfno.sht(v, lmax)
    back = sfno.isht(a, nlat, nlon)
    rel = float(jnp.linalg.norm(back - v) / jnp.linalg.norm(v))
    assert rel < 0.05, f"SHT roundtrip rel={rel}"


def test_sht_parseval_scale():
    nlat, nlon, lmax = 16, 32, 7
    v = jax.random.normal(jax.random.PRNGKey(0), (1, 1, nlat, nlon))
    a = sfno.sht(v, lmax)
    assert a.shape == (1, 1, lmax + 1, lmax + 1)
    # l < m entries must be exactly zero.
    for m in range(lmax + 1):
        for l in range(m):
            assert abs(complex(a[0, 0, l, m])) == 0.0


def test_sfno_forward_shapes():
    cfg = sfno.SfnoConfig(width=8, lmax=5, layers=2, nlat=12, nlon=24)
    params = sfno.init_params(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 3, 12, 24))
    y = sfno.forward(params, x, cfg)
    assert y.shape == (2, 3, 12, 24)
    assert np.isfinite(np.asarray(y)).all()


def test_gino_forward_shapes():
    cfg = gino.GinoConfig(n_points=32, grid=4, width=8, modes=1, layers=1)
    params = gino.init_params(jax.random.PRNGKey(0), cfg)
    feats = jax.random.normal(jax.random.PRNGKey(1), (1, 32, 7))
    to_g = jnp.abs(jax.random.normal(jax.random.PRNGKey(2), (1, 64, 32)))
    to_g = to_g / jnp.sum(to_g, -1, keepdims=True)
    from_g = jnp.abs(jax.random.normal(jax.random.PRNGKey(3), (1, 32, 64)))
    from_g = from_g / jnp.sum(from_g, -1, keepdims=True)
    y = gino.forward(params, feats, to_g, from_g, cfg)
    assert y.shape == (1, 32)
    assert np.isfinite(np.asarray(y)).all()


def test_relative_l2_properties():
    y = jax.random.normal(jax.random.PRNGKey(0), (3, 1, 8, 8))
    assert float(losses.relative_l2(y, y)) < 1e-5
    assert abs(float(losses.relative_l2(1.1 * y, y)) - 0.1) < 1e-3
    assert abs(float(losses.relative_l2(jnp.zeros_like(y), y)) - 1.0) < 1e-3


def test_relative_h1_penalizes_high_frequencies_more():
    res = 32
    ys = jnp.linspace(0, 1, res, endpoint=False)
    gy, gx = jnp.meshgrid(ys, ys, indexing="ij")
    base = jnp.sin(2 * jnp.pi * gx)[None, None]
    lo_err = base + 0.1 * jnp.sin(2 * jnp.pi * gx)[None, None]
    hi_err = base + 0.1 * jnp.sin(2 * jnp.pi * 8 * gx)[None, None]
    l2_lo = float(losses.relative_l2(lo_err, base))
    l2_hi = float(losses.relative_l2(hi_err, base))
    h1_lo = float(losses.relative_h1(lo_err, base))
    h1_hi = float(losses.relative_h1(hi_err, base))
    assert abs(l2_lo - l2_hi) < 0.02  # same L2 perturbation size
    assert h1_hi > 2.0 * h1_lo  # H1 punishes the high-frequency error


def test_grads_flow_through_all_modes():
    from compile import train_graph

    for mode in [q.FULL, q.MIXED]:
        cfg = small_fno(mode=mode, stab="tanh" if mode == q.MIXED else "none")
        names, _fwd, grads = train_graph.make_grid_graphs("fno", cfg, "h1")
        params = fno.init_params(jax.random.PRNGKey(0), cfg)
        flat = [params[n] for n in names]
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 1, 16, 16))
        y = jax.random.normal(jax.random.PRNGKey(2), (2, 1, 16, 16))
        out = grads(*flat, x, y, jnp.float32(1.0))
        loss, gs = out[0], out[1:]
        assert np.isfinite(float(loss))
        assert len(gs) == len(flat)
        total = sum(float(jnp.abs(g).sum()) for g in gs)
        assert total > 0, f"zero grads in mode {mode}"


def test_loss_scale_divides_out():
    from compile import train_graph

    cfg = small_fno()
    names, _fwd, grads = train_graph.make_grid_graphs("fno", cfg, "l2")
    params = fno.init_params(jax.random.PRNGKey(0), cfg)
    flat = [params[n] for n in names]
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 1, 16, 16))
    y = jax.random.normal(jax.random.PRNGKey(2), (2, 1, 16, 16))
    o1 = grads(*flat, x, y, jnp.float32(1.0))
    o1k = grads(*flat, x, y, jnp.float32(1024.0))
    # Reported loss is unscaled...
    assert abs(float(o1[0]) - float(o1k[0])) < 1e-5
    # ...while gradients are scaled by 1024.
    r = float(jnp.abs(o1k[1]).max() / jnp.abs(o1[1]).max())
    assert abs(r - 1024.0) / 1024.0 < 1e-3
