"""AOT path checks: the artifact matrix is well-formed and lowers to
parseable HLO text with the manifest-declared interface."""

import json
import os

import pytest

from compile import aot


def test_matrix_names_unique_and_tokenized():
    arts = aot.build_matrix()
    names = [a.name for a in arts]
    assert len(names) == len(set(names)), "duplicate artifact names"
    for a in arts:
        assert a.graph in ("fwd", "grads")
        assert a.precision in ("full", "amp", "mixed", "bf16", "fp8", "tf32")
        # The dense/geometry split covers all five paper datasets.
    datasets = {a.dataset for a in arts}
    assert datasets == {"ns", "darcy", "swe", "car", "ahmed"}


def test_matrix_covers_experiment_needs():
    arts = aot.build_matrix()
    names = {a.name for a in arts}
    # Stability study (Fig. 10 / Table 3).
    for stab in ["none", "tanh", "hardclip", "sigclip", "div"]:
        assert f"fno_ns_r32_mixed_{stab}_grads" in names
    # Table 4's 8 per-site combos.
    for bits in range(8):
        tag = "".join(
            "h" if bits & b else "f" for b in (4, 2, 1)
        )
        assert f"fno_darcy_r32_site{tag}_grads" in names
    # Super-resolution forwards.
    for res in [64, 128, 256]:
        assert f"fno_ns_r{res}_full_none_fwd" in names
        assert f"fno_ns_r{res}_mixed_tanh_fwd" in names


def test_lower_one_artifact_produces_hlo_text():
    arts = [a for a in aot.build_matrix() if a.name == "fno_darcy_r32_full_none_fwd"]
    assert len(arts) == 1
    text, entry = aot.lower_artifact(arts[0])
    assert text.startswith("HloModule"), text[:80]
    assert "fft" in text.lower()
    assert entry["params"][0]["name"] == "lift_w"
    # Interface arity: params + declared extra inputs.
    n_inputs = len(entry["params"]) + len(entry["extra_inputs"])
    assert f"parameter({n_inputs - 1})" in text


def test_grads_artifact_has_loss_scale_input():
    arts = [a for a in aot.build_matrix() if a.name == "fno_darcy_r32_full_none_grads"]
    _text, entry = aot.lower_artifact(arts[0])
    assert entry["extra_inputs"][-1]["name"] == "loss_scale"
    assert entry["extra_inputs"][-1]["shape"] == []


@pytest.mark.skipif(
    not os.path.exists(os.path.join(os.path.dirname(__file__), "../../artifacts/manifest.json")),
    reason="artifacts not built",
)
def test_manifest_consistent_with_files():
    root = os.path.join(os.path.dirname(__file__), "../../artifacts")
    manifest = json.load(open(os.path.join(root, "manifest.json")))
    assert manifest["version"] == 1
    for entry in manifest["artifacts"]:
        path = os.path.join(root, entry["file"])
        assert os.path.exists(path), entry["file"]
        head = open(path).read(64)
        assert head.startswith("HloModule")
